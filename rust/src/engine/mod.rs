//! The unified prediction engine — the one prediction path in the
//! library.
//!
//! Everything that turns `(model, batch, origin)` into destination
//! predictions flows through [`PredictionEngine`]:
//!
//! * a **content-keyed LRU trace cache** over
//!   `(model, batch, origin, precision)` — tracking a model on the
//!   simulator is the expensive, reusable step (the analogue of the
//!   paper's profiling run), so repeated requests skip it entirely.
//!   Hit/miss counters are exported via [`PredictionEngine::stats`];
//! * a **memoized occupancy/wave-size table** ([`memo::WaveTable`])
//!   keyed by `(device, LaunchConfig)`, shared by the ground-truth
//!   simulator and the predictor's wave scaling;
//! * a **multi-destination fan-out** ([`PredictionEngine::fan_out`])
//!   that predicts one cached trace onto every destination GPU,
//!   resolving the per-trace metrics set once and parallelizing across
//!   destinations with a `std::thread` worker pool;
//! * a **rank** API ([`PredictionEngine::rank`]) that answers the
//!   paper's Fig. 1 question as a single call: every destination GPU
//!   ordered by cost-normalized throughput (rentable devices first,
//!   descending; unpriced devices after, by raw throughput).
//!
//! The TCP front end ([`crate::coordinator`]), the CLI, and the
//! experiment harness are all thin layers over this engine.

pub mod cache;
pub mod memo;

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering::Relaxed};
use std::sync::{Arc, Mutex};

use crate::cost;
use crate::device::Device;
use crate::lowering::Precision;
use crate::models;
use crate::predict::{amp, HybridPredictor, PredictedTrace};
use crate::tracker::{OperationTracker, Trace};
use crate::Result;

use cache::LruCache;

/// Trace-cache key: model name, batch size, origin device, and the
/// precision the iteration was *tracked* at.
pub type TraceKey = (String, usize, Device, Precision);

/// Default number of traces kept hot. A trace is a few hundred KB, so
/// this bounds the cache at tens of MB.
pub const DEFAULT_TRACE_CAPACITY: usize = 128;

/// One engine prediction: the (shared) origin trace it was made from and
/// the predicted destination iteration.
pub struct EnginePrediction {
    pub trace: Arc<Trace>,
    pub pred: PredictedTrace,
}

/// One entry of a [`Ranking`].
pub struct RankEntry {
    pub dest: Device,
    pub pred: PredictedTrace,
    /// Samples/s per rental $/hr; `None` for devices not offered for rent.
    pub cost_normalized_throughput: Option<f64>,
}

/// The result of [`PredictionEngine::rank`]: every destination, best
/// decision first.
pub struct Ranking {
    pub trace: Arc<Trace>,
    pub entries: Vec<RankEntry>,
}

/// The ordering used by [`PredictionEngine::rank`] (and the CLI table):
/// rentable devices first by descending cost-normalized throughput, then
/// unpriced devices by descending raw throughput. Each side is
/// `(cost_normalized_throughput, throughput)`.
pub fn rank_order(a: (Option<f64>, f64), b: (Option<f64>, f64)) -> std::cmp::Ordering {
    use std::cmp::Ordering;
    match (a.0, b.0) {
        (Some(x), Some(y)) => y.total_cmp(&x),
        (Some(_), None) => Ordering::Less,
        (None, Some(_)) => Ordering::Greater,
        (None, None) => b.1.total_cmp(&a.1),
    }
}

/// Counter snapshot for benches, tests, and operational visibility.
#[derive(Debug, Clone, Copy)]
pub struct EngineStats {
    /// Trace-cache hits (requests that skipped the tracking pipeline).
    pub trace_hits: u64,
    /// Trace-cache misses (tracking-pipeline executions).
    pub trace_misses: u64,
    /// Traces currently resident.
    pub trace_entries: usize,
    /// Wave-table hits/misses. **Process-wide**, not per engine: the
    /// wave table is shared with the simulator and every other engine
    /// in the process, so these count all of that activity.
    pub wave_hits: u64,
    pub wave_misses: u64,
}

/// The shared prediction engine. `Send + Sync`: one engine serves any
/// number of connection threads.
pub struct PredictionEngine {
    predictor: HybridPredictor,
    traces: Mutex<LruCache<TraceKey, Arc<Trace>>>,
    /// Per-key build gates: concurrent misses on the *same* key wait for
    /// the first builder instead of re-running the tracking pipeline
    /// (distinct keys still track in parallel).
    building: Mutex<std::collections::HashMap<TraceKey, Arc<Mutex<()>>>>,
    trace_hits: AtomicU64,
    trace_misses: AtomicU64,
    workers: usize,
}

impl PredictionEngine {
    /// Build around any predictor with the default cache capacity.
    pub fn new(predictor: HybridPredictor) -> Self {
        Self::with_capacity(predictor, DEFAULT_TRACE_CAPACITY)
    }

    /// Build with an explicit trace-cache capacity.
    pub fn with_capacity(predictor: HybridPredictor, capacity: usize) -> Self {
        let workers = std::thread::available_parallelism()
            .map(|p| p.get())
            .unwrap_or(4)
            .clamp(1, 8);
        PredictionEngine {
            predictor,
            traces: Mutex::new(LruCache::new(capacity)),
            building: Mutex::new(std::collections::HashMap::new()),
            trace_hits: AtomicU64::new(0),
            trace_misses: AtomicU64::new(0),
            workers,
        }
    }

    /// Wave-scaling-only engine (no MLP artifacts required).
    pub fn wave_only() -> Self {
        Self::new(HybridPredictor::wave_only())
    }

    /// The paper's full hybrid configuration from an artifacts directory.
    pub fn from_artifacts(dir: &str) -> Result<Self> {
        Ok(Self::new(crate::runtime::predictor_from_artifacts(dir)?))
    }

    /// Override the fan-out worker-pool width (defaults to the machine's
    /// parallelism, capped at 8).
    pub fn with_workers(mut self, workers: usize) -> Self {
        self.workers = workers.max(1);
        self
    }

    pub fn predictor(&self) -> &HybridPredictor {
        &self.predictor
    }

    /// Get or build the FP32 origin trace for a zoo model (memoized).
    /// The tracker profiles FP32 — the paper measures FP32 and *predicts*
    /// AMP (§6.1.2).
    pub fn trace(&self, model: &str, batch: usize, origin: Device) -> Result<Arc<Trace>> {
        self.trace_with_precision(model, batch, origin, Precision::Fp32)
    }

    /// Get or build a trace tracked at an explicit precision (memoized).
    pub fn trace_with_precision(
        &self,
        model: &str,
        batch: usize,
        origin: Device,
        precision: Precision,
    ) -> Result<Arc<Trace>> {
        let key = (model.to_string(), batch, origin, precision);
        if let Some(t) = self.traces.lock().unwrap().get(&key) {
            self.trace_hits.fetch_add(1, Relaxed);
            return Ok(t);
        }
        // Miss: serialize builders of the *same* key so a thundering herd
        // of identical cold requests tracks exactly once.
        let gate = self
            .building
            .lock()
            .unwrap()
            .entry(key.clone())
            .or_insert_with(|| Arc::new(Mutex::new(())))
            .clone();
        // Recover a poisoned gate: a builder that panicked mid-track must
        // not permanently wedge this key for the life of the service.
        let _build_guard = gate.lock().unwrap_or_else(|poisoned| poisoned.into_inner());
        // Double-check: the first builder may have just filled the cache.
        if let Some(t) = self.traces.lock().unwrap().get(&key) {
            self.trace_hits.fetch_add(1, Relaxed);
            return Ok(t);
        }
        let Some(graph) = models::by_name(model, batch) else {
            self.building.lock().unwrap().remove(&key);
            anyhow::bail!("unknown model {model:?}");
        };
        // Count a miss only when the tracking pipeline actually runs.
        self.trace_misses.fetch_add(1, Relaxed);
        let trace = Arc::new(
            OperationTracker::new(origin)
                .with_precision(precision)
                .track(&graph),
        );
        self.traces.lock().unwrap().insert(key.clone(), trace.clone());
        self.building.lock().unwrap().remove(&key);
        Ok(trace)
    }

    /// Predict one `(model, batch, origin) → dest` pair, tracking (or
    /// reusing) the origin trace. `precision` selects the prediction:
    /// FP32 directly, or the AMP transform composed on top (§6.1.2).
    pub fn predict(
        &self,
        model: &str,
        batch: usize,
        origin: Device,
        dest: Device,
        precision: Precision,
    ) -> Result<EnginePrediction> {
        anyhow::ensure!(batch > 0, "batch must be positive");
        let trace = self.trace(model, batch, origin)?;
        let pred = self.predict_trace(&trace, dest, precision);
        Ok(EnginePrediction { trace, pred })
    }

    /// Predict an already-tracked trace onto one destination.
    pub fn predict_trace(&self, trace: &Trace, dest: Device, precision: Precision) -> PredictedTrace {
        let profiled = self.predictor.metrics_policy.profiled_kernels(trace);
        self.predict_one(trace, dest, precision, profiled.as_ref())
    }

    fn predict_one(
        &self,
        trace: &Trace,
        dest: Device,
        precision: Precision,
        profiled: Option<&std::collections::HashSet<u64>>,
    ) -> PredictedTrace {
        let fp32 = self.predictor.predict_with_profiled(trace, dest, profiled);
        match precision {
            Precision::Fp32 => fp32,
            Precision::Amp => amp::amp_transform(&fp32, trace),
        }
    }

    /// Predict one trace onto *all* destinations in a single pass over
    /// the trace metadata: the per-trace profiled-kernel set is resolved
    /// once and shared, per-kernel launch metadata hits the process-wide
    /// wave table, and destinations are spread over a `std::thread`
    /// worker pool. Results come back in `dests` order and are
    /// bit-identical to sequential [`PredictionEngine::predict_trace`]
    /// calls.
    pub fn fan_out(
        &self,
        trace: &Trace,
        dests: &[Device],
        precision: Precision,
    ) -> Vec<PredictedTrace> {
        if dests.is_empty() {
            return Vec::new();
        }
        let profiled = self.predictor.metrics_policy.profiled_kernels(trace);
        let profiled_ref = profiled.as_ref();
        if dests.len() == 1 {
            return vec![self.predict_one(trace, dests[0], precision, profiled_ref)];
        }

        let workers = self.workers.min(dests.len());
        let next = AtomicUsize::new(0);
        let next_ref = &next;
        let (tx, rx) = std::sync::mpsc::channel::<(usize, PredictedTrace)>();
        let mut out: Vec<Option<PredictedTrace>> = Vec::with_capacity(dests.len());
        out.resize_with(dests.len(), || None);
        std::thread::scope(|s| {
            for _ in 0..workers {
                let tx = tx.clone();
                s.spawn(move || loop {
                    let i = next_ref.fetch_add(1, Relaxed);
                    if i >= dests.len() {
                        break;
                    }
                    let pred = self.predict_one(trace, dests[i], precision, profiled_ref);
                    if tx.send((i, pred)).is_err() {
                        break;
                    }
                });
            }
            drop(tx);
            for (i, pred) in rx {
                out[i] = Some(pred);
            }
        });
        out.into_iter()
            .map(|p| p.expect("every destination predicted"))
            .collect()
    }

    /// The paper's Fig. 1 decision as one call: track (or reuse) the
    /// origin trace once, fan out to every destination, and rank by
    /// cost-normalized throughput. Rentable devices come first in
    /// descending samples/s/$; devices without a rental price follow,
    /// ordered by raw throughput. Ties keep `dests` order.
    pub fn rank(
        &self,
        model: &str,
        batch: usize,
        origin: Device,
        dests: &[Device],
        precision: Precision,
    ) -> Result<Ranking> {
        anyhow::ensure!(batch > 0, "batch must be positive");
        anyhow::ensure!(!dests.is_empty(), "rank needs at least one destination");
        let trace = self.trace(model, batch, origin)?;
        let preds = self.fan_out(&trace, dests, precision);
        let mut entries: Vec<RankEntry> = dests
            .iter()
            .zip(preds)
            .map(|(&dest, pred)| {
                let cnt = cost::cost_normalized_throughput(dest, pred.throughput());
                RankEntry {
                    dest,
                    pred,
                    cost_normalized_throughput: cnt,
                }
            })
            .collect();
        entries.sort_by(|a, b| {
            rank_order(
                (a.cost_normalized_throughput, a.pred.throughput()),
                (b.cost_normalized_throughput, b.pred.throughput()),
            )
        });
        Ok(Ranking { trace, entries })
    }

    /// Counter snapshot (trace cache + shared wave table).
    pub fn stats(&self) -> EngineStats {
        let (wave_hits, wave_misses) = memo::WaveTable::global().counters();
        EngineStats {
            trace_hits: self.trace_hits.load(Relaxed),
            trace_misses: self.trace_misses.load(Relaxed),
            trace_entries: self.traces.lock().unwrap().len(),
            wave_hits,
            wave_misses,
        }
    }

    /// Drop every cached trace (the counters are preserved). Used by the
    /// cold-path benches.
    pub fn clear_trace_cache(&self) {
        self.traces.lock().unwrap().clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::ALL_DEVICES;

    fn engine() -> PredictionEngine {
        PredictionEngine::wave_only()
    }

    #[test]
    fn trace_cache_hits_and_counts() {
        let e = engine();
        let a = e.trace("mlp", 16, Device::T4).unwrap();
        let b = e.trace("mlp", 16, Device::T4).unwrap();
        assert!(Arc::ptr_eq(&a, &b), "second lookup must hit the cache");
        let s = e.stats();
        assert_eq!(s.trace_misses, 1);
        assert_eq!(s.trace_hits, 1);
        assert_eq!(s.trace_entries, 1);
    }

    #[test]
    fn distinct_keys_do_not_collide() {
        let e = engine();
        e.trace("mlp", 16, Device::T4).unwrap();
        e.trace("mlp", 32, Device::T4).unwrap();
        e.trace("mlp", 16, Device::V100).unwrap();
        e.trace_with_precision("mlp", 16, Device::T4, Precision::Amp)
            .unwrap();
        let s = e.stats();
        assert_eq!(s.trace_misses, 4);
        assert_eq!(s.trace_entries, 4);
    }

    #[test]
    fn unknown_model_is_an_error_not_a_miss() {
        let e = engine();
        assert!(e.trace("not_a_model", 16, Device::T4).is_err());
        assert_eq!(e.stats().trace_misses, 0);
    }

    #[test]
    fn lru_capacity_bounds_entries() {
        let e = PredictionEngine::with_capacity(HybridPredictor::wave_only(), 2);
        for batch in [1usize, 2, 4] {
            e.trace("mlp", batch, Device::T4).unwrap();
        }
        assert_eq!(e.stats().trace_entries, 2);
        // The least recently used (batch 1) was evicted; re-requesting it
        // re-tracks.
        e.trace("mlp", 1, Device::T4).unwrap();
        assert_eq!(e.stats().trace_misses, 4);
    }

    #[test]
    fn concurrent_identical_requests_track_once() {
        let e = engine();
        std::thread::scope(|s| {
            for _ in 0..8 {
                s.spawn(|| e.trace("mlp", 16, Device::T4).unwrap());
            }
        });
        let st = e.stats();
        assert_eq!(st.trace_misses, 1, "a thundering herd must track exactly once");
        assert_eq!(st.trace_hits, 7);
    }

    #[test]
    fn fan_out_matches_sequential_predictions() {
        let e = engine();
        let trace = e.trace("mlp", 32, Device::T4).unwrap();
        let fanned = e.fan_out(&trace, &ALL_DEVICES, Precision::Fp32);
        assert_eq!(fanned.len(), ALL_DEVICES.len());
        for (dest, pred) in ALL_DEVICES.iter().zip(&fanned) {
            assert_eq!(pred.dest, *dest, "results must come back in dests order");
            let seq = e.predict_trace(&trace, *dest, Precision::Fp32);
            assert_eq!(
                pred.run_time_ms(),
                seq.run_time_ms(),
                "{dest}: fan-out must be bit-identical to sequential"
            );
        }
    }

    #[test]
    fn fan_out_amp_matches_sequential() {
        let e = engine();
        let trace = e.trace("mlp", 32, Device::P4000).unwrap();
        let dests = [Device::V100, Device::Rtx2080Ti];
        let fanned = e.fan_out(&trace, &dests, Precision::Amp);
        for (dest, pred) in dests.iter().zip(&fanned) {
            let seq = e.predict_trace(&trace, *dest, Precision::Amp);
            assert_eq!(pred.run_time_ms(), seq.run_time_ms());
        }
    }

    #[test]
    fn fan_out_single_worker_still_covers_all() {
        let e = PredictionEngine::wave_only().with_workers(1);
        let trace = e.trace("mlp", 8, Device::T4).unwrap();
        let fanned = e.fan_out(&trace, &ALL_DEVICES, Precision::Fp32);
        assert_eq!(fanned.len(), ALL_DEVICES.len());
    }

    #[test]
    fn rank_tracks_once_and_sorts_by_cost_normalized_throughput() {
        let e = engine();
        let ranking = e
            .rank("mlp", 32, Device::T4, &ALL_DEVICES, Precision::Fp32)
            .unwrap();
        assert_eq!(ranking.entries.len(), ALL_DEVICES.len());
        assert_eq!(e.stats().trace_misses, 1, "one tracking pass for the whole ranking");

        // Priced devices first, descending; unpriced after, by throughput.
        let first_unpriced = ranking
            .entries
            .iter()
            .position(|en| en.cost_normalized_throughput.is_none())
            .unwrap_or(ranking.entries.len());
        for en in &ranking.entries[..first_unpriced] {
            assert!(en.cost_normalized_throughput.is_some());
        }
        for en in &ranking.entries[first_unpriced..] {
            assert!(en.cost_normalized_throughput.is_none());
        }
        for pair in ranking.entries[..first_unpriced].windows(2) {
            assert!(
                pair[0].cost_normalized_throughput.unwrap()
                    >= pair[1].cost_normalized_throughput.unwrap()
            );
        }
        for pair in ranking.entries[first_unpriced..].windows(2) {
            assert!(pair[0].pred.throughput() >= pair[1].pred.throughput());
        }
    }

    #[test]
    fn rank_matches_individual_predictions() {
        let e = engine();
        let ranking = e
            .rank("mlp", 16, Device::P4000, &ALL_DEVICES, Precision::Fp32)
            .unwrap();
        for en in &ranking.entries {
            let single = e
                .predict("mlp", 16, Device::P4000, en.dest, Precision::Fp32)
                .unwrap();
            assert!(
                (en.pred.run_time_ms() - single.pred.run_time_ms()).abs() < 1e-12,
                "{}: ranked vs individual prediction",
                en.dest
            );
        }
        // All the individual requests above were cache hits.
        let s = e.stats();
        assert_eq!(s.trace_misses, 1);
        assert_eq!(s.trace_hits as usize, ALL_DEVICES.len());
    }

    #[test]
    fn rank_rejects_bad_input() {
        let e = engine();
        assert!(e.rank("mlp", 0, Device::T4, &ALL_DEVICES, Precision::Fp32).is_err());
        assert!(e.rank("mlp", 8, Device::T4, &[], Precision::Fp32).is_err());
        assert!(e
            .rank("not_a_model", 8, Device::T4, &ALL_DEVICES, Precision::Fp32)
            .is_err());
    }

    #[test]
    fn clear_trace_cache_forces_retrack() {
        let e = engine();
        e.trace("mlp", 16, Device::T4).unwrap();
        e.clear_trace_cache();
        assert_eq!(e.stats().trace_entries, 0);
        e.trace("mlp", 16, Device::T4).unwrap();
        assert_eq!(e.stats().trace_misses, 2);
    }
}
