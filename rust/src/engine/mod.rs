//! The unified prediction engine — the one prediction path in the
//! library.
//!
//! Everything that turns `(model, batch, origin)` into destination
//! predictions flows through [`PredictionEngine`], as a
//! **track → analyze → evaluate** pipeline:
//!
//! * **track** — run one training iteration on the simulator (the
//!   analogue of the paper's profiling run) to produce a
//!   [`Trace`];
//! * **analyze** — compile the trace into an [`AnalyzedPlan`]
//!   ([`crate::plan`]): a flat structure-of-arrays arena holding
//!   everything destination-independent — kernel launch metadata,
//!   batched wave sizes for every `(launch shape, device)` pair,
//!   policy-resolved γ, AMP factors, and MLP feature rows. Built once
//!   per trace;
//! * **evaluate** — per-destination scaling arithmetic over the plan's
//!   arrays ([`crate::predict::HybridPredictor::evaluate`]): no lock,
//!   no hashing, no feature recomputation in the fan-out loop.
//!
//! Around that pipeline the engine provides:
//!
//! * a **sharded, content-keyed LRU cache** over
//!   `(model, batch, origin, precision)` holding the trace *and* its
//!   plan ([`AnalyzedTrace`]), so repeated requests skip both tracking
//!   and analysis. The cache ([`cache::ShardedLru`]) is lock-striped:
//!   hits take a shard *read* guard and clone an `Arc`, misses gate on
//!   a per-key singleflight (a thundering herd tracks once; a build in
//!   one shard never blocks a hit in another), and all counters are
//!   `AtomicU64`s snapshotted without locking by
//!   [`PredictionEngine::stats`];
//! * a **persistent shared compute pool** ([`pool::WorkerPool`]) — a
//!   bounded submission queue feeding fixed workers, spawned once per
//!   engine, sized by [`PredictionEngine::with_workers`] or
//!   `HABITAT_WORKERS` (queue depth via `HABITAT_QUEUE_DEPTH`). Fan-out
//!   helpers and the TCP service's request handlers draw from this one
//!   budget; [`PredictionEngine::fan_out`] submits helpers without ever
//!   blocking and always evaluates on the calling thread too, so a
//!   `rank` running *on* a pool worker can never deadlock the pool;
//! * the **memoized occupancy/wave-size table** ([`memo::WaveTable`])
//!   shared with the ground-truth simulator (consulted only at
//!   plan-build time);
//! * a **rank** API ([`PredictionEngine::rank`]) answering the paper's
//!   Fig. 1 question in one call: every destination GPU ordered by
//!   cost-normalized throughput.
//!
//! The TCP front end ([`crate::coordinator`]), the CLI, and the
//! experiment harness are all thin layers over this engine.

pub mod cache;
pub mod memo;
pub mod metrics;
pub mod pool;
pub mod store;

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering::Relaxed};
use std::sync::mpsc;
use std::sync::{Arc, OnceLock};

use crate::comm;
use crate::comm::{ClusterParams, ClusterPrediction, Topology};
use crate::cost;
use crate::device::registry::RegisterError;
use crate::device::{Device, NewDevice};
use crate::lowering::Precision;
use crate::models;
use crate::plan::{AnalyzedPlan, AnalyzedTrace};
use crate::predict::{HybridPredictor, PredictedTrace};
use crate::tracker::{OperationTracker, Trace};
use crate::Result;

use cache::{Claim, ShardedLru};
use pool::WorkerPool;
use store::{PlanStore, StoredKind};

/// Trace-cache key: model name, batch size, origin device, and the
/// precision the iteration was *tracked* at.
pub type TraceKey = (String, usize, Device, Precision);

/// Default number of trace+plan entries kept hot. An entry is a few
/// hundred KB, so this bounds the cache at tens of MB.
pub const DEFAULT_TRACE_CAPACITY: usize = 128;

/// Default number of client-uploaded traces (`submit_trace`) kept hot,
/// keyed by content hash.
pub const DEFAULT_UPLOAD_CAPACITY: usize = 256;

/// Environment variable overriding the fan-out worker-pool width.
pub const WORKERS_ENV: &str = "HABITAT_WORKERS";

/// One engine prediction: the (shared) origin trace it was made from and
/// the predicted destination iteration.
pub struct EnginePrediction {
    pub trace: Arc<Trace>,
    pub pred: PredictedTrace,
}

/// One entry of a [`Ranking`].
pub struct RankEntry {
    pub dest: Device,
    pub pred: PredictedTrace,
    /// Samples/s per rental $/hr; `None` for devices not offered for rent.
    pub cost_normalized_throughput: Option<f64>,
}

/// The result of [`PredictionEngine::rank`]: every destination, best
/// decision first.
pub struct Ranking {
    pub trace: Arc<Trace>,
    pub entries: Vec<RankEntry>,
}

/// One `(plan, destination set)` sweep of a multi-trace evaluation
/// ([`PredictionEngine::evaluate_many`]). The plan rides an `Arc` bump
/// (no clone of the arena) and the destination slice is borrowed, so
/// building a job list allocates nothing beyond the list itself.
pub struct SweepJob<'a> {
    pub plan: Arc<AnalyzedPlan>,
    pub dests: &'a [Device],
    pub precision: Precision,
}

/// Reusable arena of per-destination iteration times filled by
/// [`PredictionEngine::evaluate_many_times`]: one flat `times` buffer
/// with one contiguous row per job, in the job's caller destination
/// order. Capacity is retained across calls, so steady-state
/// multi-trace sweeps through a warm arena allocate nothing (pinned by
/// `rust/tests/batched_alloc.rs`).
#[derive(Default)]
pub struct SweepTimes {
    times: Vec<f64>,
    /// `offsets[j]..offsets[j + 1]` is job `j`'s row; one trailing
    /// entry holds the total.
    offsets: Vec<usize>,
}

impl SweepTimes {
    pub fn new() -> Self {
        Self::default()
    }

    /// Size the arena for `jobs` (capacity-reusing `clear` + `resize`).
    fn reset(&mut self, jobs: &[SweepJob<'_>]) {
        self.offsets.clear();
        let mut total = 0usize;
        self.offsets.push(0);
        for job in jobs {
            total += job.dests.len();
            self.offsets.push(total);
        }
        self.times.clear();
        self.times.resize(total, 0.0);
    }

    /// Jobs in the last fill.
    pub fn n_jobs(&self) -> usize {
        self.offsets.len().saturating_sub(1)
    }

    /// Job `j`'s predicted iteration times (ms), one per caller
    /// destination, in the job's destination order — each bit-identical
    /// to [`crate::plan::EvalScratch::run_time_ms`] for that sweep.
    pub fn job(&self, j: usize) -> &[f64] {
        &self.times[self.offsets[j]..self.offsets[j + 1]]
    }
}

/// One `(model, batch, origin)` row of a [`PredictionEngine::rank_many`]
/// request.
#[derive(Debug, Clone)]
pub struct RankManyItem {
    pub model: String,
    pub batch: usize,
    pub origin: Device,
}

/// One `(topology, world)` cell of a [`ClusterReport`].
pub struct ClusterCell {
    pub topology: Topology,
    pub world: usize,
    pub pred: ClusterPrediction,
    /// Global samples/s per total rental $/hr (`world ×` the device
    /// price); `None` for devices not offered for rent.
    pub cost_normalized_throughput: Option<f64>,
}

/// The result of [`PredictionEngine::predict_cluster`]: one destination
/// GPU swept across a topology × world-size grid. `configs` is
/// topology-major in the caller's order.
pub struct ClusterReport {
    pub trace: Arc<Trace>,
    pub dest: Device,
    /// Per-replica single-GPU compute time (shared by every cell), ms.
    pub compute_ms: f64,
    pub configs: Vec<ClusterCell>,
}

/// One entry of a [`ClusterRanking`].
pub struct ClusterRankEntry {
    pub dest: Device,
    pub topology: Topology,
    pub world: usize,
    pub pred: ClusterPrediction,
    pub cost_normalized_throughput: Option<f64>,
}

/// The result of [`PredictionEngine::rank_cluster`]: every
/// (destination, topology, world) configuration, best decision first
/// (same ordering as [`rank_order`], with the fleet price as the cost).
pub struct ClusterRanking {
    pub trace: Arc<Trace>,
    pub entries: Vec<ClusterRankEntry>,
}

/// The ordering used by [`PredictionEngine::rank`] (and the CLI table):
/// rentable devices first by descending cost-normalized throughput, then
/// unpriced devices by descending raw throughput. Each side is
/// `(cost_normalized_throughput, throughput)`.
pub fn rank_order(a: (Option<f64>, f64), b: (Option<f64>, f64)) -> std::cmp::Ordering {
    use std::cmp::Ordering;
    match (a.0, b.0) {
        (Some(x), Some(y)) => y.total_cmp(&x),
        (Some(_), None) => Ordering::Less,
        (None, Some(_)) => Ordering::Greater,
        (None, None) => b.1.total_cmp(&a.1),
    }
}

/// Counter snapshot for benches, tests, and operational visibility
/// (served over the wire as the `stats` request).
#[derive(Debug, Clone, Copy)]
pub struct EngineStats {
    /// Cache hits (requests that skipped the tracking pipeline).
    pub trace_hits: u64,
    /// Cache misses (tracking-pipeline executions).
    pub trace_misses: u64,
    /// Trace+plan entries currently resident.
    pub trace_entries: usize,
    /// Distinct traces accepted through
    /// [`PredictionEngine::submit_trace`] (idempotent re-submissions
    /// not counted).
    pub trace_uploads: u64,
    /// Uploaded trace+plan entries currently resident.
    pub uploaded_entries: usize,
    /// Devices currently in the registry (built-ins + runtime
    /// registrations).
    pub devices: usize,
    /// [`AnalyzedPlan`] compilations (cache misses plus one-off
    /// [`PredictionEngine::analyze`] builds for external traces). The
    /// plan rides the same cache entry as its trace, so cached-plan
    /// reuses are exactly `trace_hits`.
    pub plan_builds: u64,
    /// Wave-table hits/misses. **Process-wide**, not per engine: the
    /// wave table is shared with the simulator and every other engine
    /// in the process, so these count all of that activity.
    pub wave_hits: u64,
    pub wave_misses: u64,
    /// Persistent fan-out worker-pool width.
    pub workers: usize,
    /// Cache misses served from the persistent plan store instead of
    /// the tracking/compilation pipeline (always 0 with no store).
    pub store_hits: u64,
    /// Compilations that checked the attached store and found no
    /// usable record (always 0 with no store).
    pub store_misses: u64,
    /// Records restored from disk into the caches at
    /// [`PredictionEngine::attach_store`] time.
    pub warm_restores: u64,
    /// Per-device lane rows filled by the work-claiming parallel plan
    /// builder (serial fallback builds contribute 0).
    pub parallel_build_chunks: u64,
    /// Wire requests recorded by the dispatcher across every transport
    /// (see [`metrics::ServiceMetrics`]); 0 for engines never served
    /// over the wire.
    pub requests: u64,
    /// Wire requests whose reply was an error.
    pub request_errors: u64,
    /// The evaluation-lane backend the sweeps run on
    /// ([`crate::util::simdf64::backend`]): `"avx2"` or `"scalar"`
    /// (forced by `HABITAT_SIMD=off`, or no AVX2 on this machine).
    /// Both backends produce bit-identical predictions.
    pub simd: &'static str,
}

/// The shared prediction engine. `Send + Sync`: one engine serves any
/// number of connection threads, and under concurrency the hot path
/// (cache hit → `Arc` clone → lock-free evaluate) takes only a shard
/// read guard — no global mutex anywhere on it.
pub struct PredictionEngine {
    predictor: Arc<HybridPredictor>,
    /// Sharded trace+plan LRU with per-key singleflight build gates:
    /// concurrent misses on the *same* key wait for the first builder
    /// instead of re-running the tracking pipeline, and builds of
    /// distinct keys never wait on each other.
    entries: ShardedLru<TraceKey, AnalyzedTrace>,
    /// Client-uploaded traces (`submit_trace`), analyzed once and keyed
    /// by a content hash of their canonical JSON — arbitrary non-zoo
    /// workloads flow through the same plan/evaluate machinery as the
    /// zoo models. Sharded like `entries`.
    uploads: ShardedLru<String, AnalyzedTrace>,
    trace_hits: AtomicU64,
    trace_misses: AtomicU64,
    trace_uploads: AtomicU64,
    plan_builds: AtomicU64,
    store_hits: AtomicU64,
    store_misses: AtomicU64,
    warm_restores: AtomicU64,
    parallel_build_chunks: AtomicU64,
    /// Optional persistent plan store ([`store::PlanStore`]): attached
    /// explicitly via [`PredictionEngine::with_store`] /
    /// [`PredictionEngine::attach_store`] (never implicitly from the
    /// environment, so tests and libraries stay hermetic). When
    /// present, compiled plans are persisted write-behind on the
    /// compute pool and cache misses consult the store before paying
    /// for the tracking pipeline.
    store: Option<Arc<PlanStore>>,
    /// Desired compute-pool width; the pool itself is spawned lazily on
    /// the first use that needs it, so engines that only evaluate
    /// sequentially never spawn threads and
    /// [`PredictionEngine::with_workers`] never discards a spawned pool.
    workers: usize,
    /// Bounded submission-queue depth for the compute pool.
    queue_depth: usize,
    pool: OnceLock<WorkerPool>,
    /// Per-op wire-request counters and latency histograms, fed by the
    /// coordinator dispatcher and rendered on `GET /metrics`.
    metrics: metrics::ServiceMetrics,
}

impl PredictionEngine {
    /// Build around any predictor with the default cache capacity.
    pub fn new(predictor: HybridPredictor) -> Self {
        Self::with_capacity(predictor, DEFAULT_TRACE_CAPACITY)
    }

    /// Build with an explicit trace-cache capacity. The fan-out pool is
    /// sized from `HABITAT_WORKERS` if set, else the machine's available
    /// parallelism capped at 8 (see [`PredictionEngine::with_workers`]).
    pub fn with_capacity(predictor: HybridPredictor, capacity: usize) -> Self {
        let workers = std::env::var(WORKERS_ENV)
            .ok()
            .and_then(|v| v.parse::<usize>().ok())
            .filter(|&n| n > 0)
            .unwrap_or_else(|| {
                std::thread::available_parallelism()
                    .map(|p| p.get())
                    .unwrap_or(4)
                    .clamp(1, 8)
            });
        PredictionEngine {
            predictor: Arc::new(predictor),
            entries: ShardedLru::new(capacity),
            uploads: ShardedLru::new(DEFAULT_UPLOAD_CAPACITY),
            trace_hits: AtomicU64::new(0),
            trace_misses: AtomicU64::new(0),
            trace_uploads: AtomicU64::new(0),
            plan_builds: AtomicU64::new(0),
            store_hits: AtomicU64::new(0),
            store_misses: AtomicU64::new(0),
            warm_restores: AtomicU64::new(0),
            parallel_build_chunks: AtomicU64::new(0),
            store: None,
            workers,
            queue_depth: pool::queue_depth_from_env(),
            pool: OnceLock::new(),
            metrics: metrics::ServiceMetrics::new(),
        }
    }

    /// Wave-scaling-only engine (no MLP artifacts required).
    pub fn wave_only() -> Self {
        Self::new(HybridPredictor::wave_only())
    }

    /// Attach a persistent plan store at `dir` (created if absent) and
    /// **warm-restore** it: every valid record on disk is decoded,
    /// reassembled bit-identically (`AnalyzedPlan::from_parts`), and
    /// installed in the trace/upload caches, so a restarted service
    /// serves its whole zoo without recompiling anything. Invalid
    /// records (truncated, corrupt, stale format, different metrics
    /// policy) are skipped silently — they rebuild and re-persist on
    /// first use. From here on, plan builds persist write-behind.
    pub fn attach_store<P: AsRef<std::path::Path>>(&mut self, dir: P) -> Result<()> {
        let store = Arc::new(PlanStore::open(dir, &self.predictor.metrics_policy)?);
        for id in store.ids() {
            let Some((kind, entry)) = store.load(&id) else {
                continue;
            };
            match kind {
                StoredKind::Zoo => {
                    let key: TraceKey = (
                        entry.trace.model.clone(),
                        entry.trace.batch_size,
                        entry.trace.origin,
                        entry.trace.precision,
                    );
                    self.entries.insert(key, entry);
                }
                StoredKind::Upload => self.uploads.insert(id, entry),
            }
            self.warm_restores.fetch_add(1, Relaxed);
        }
        self.store = Some(store);
        Ok(())
    }

    /// Builder-style [`PredictionEngine::attach_store`].
    pub fn with_store<P: AsRef<std::path::Path>>(mut self, dir: P) -> Result<Self> {
        self.attach_store(dir)?;
        Ok(self)
    }

    /// The attached persistent plan store, if any.
    pub fn store(&self) -> Option<&PlanStore> {
        self.store.as_deref()
    }

    /// The paper's full hybrid configuration from an artifacts directory.
    pub fn from_artifacts(dir: &str) -> Result<Self> {
        Ok(Self::new(crate::runtime::predictor_from_artifacts(dir)?))
    }

    /// Set the persistent compute-pool width (if a pool was already
    /// spawned, its threads are joined and a new one is spawned lazily).
    pub fn with_workers(mut self, workers: usize) -> Self {
        self.workers = workers.max(1);
        self.pool = OnceLock::new();
        self
    }

    /// Set the compute pool's bounded submission-queue depth (same
    /// respawn semantics as [`PredictionEngine::with_workers`]).
    pub fn with_queue_depth(mut self, queue_depth: usize) -> Self {
        self.queue_depth = queue_depth.max(1);
        self.pool = OnceLock::new();
        self
    }

    /// Persistent compute-pool width.
    pub fn workers(&self) -> usize {
        self.pool.get().map_or(self.workers, WorkerPool::size)
    }

    /// Bounded submission-queue depth of the compute pool.
    pub fn queue_depth(&self) -> usize {
        self.pool
            .get()
            .map_or(self.queue_depth, WorkerPool::queue_depth)
    }

    /// The persistent shared compute pool, spawned on first use. The
    /// TCP service submits request jobs here ([`WorkerPool::try_execute`]
    /// — a full queue is its backpressure signal), and
    /// [`PredictionEngine::fan_out`] adds evaluation helpers, so rank
    /// fan-outs and concurrent clients share one bounded budget.
    pub fn pool(&self) -> &WorkerPool {
        self.pool
            .get_or_init(|| WorkerPool::with_queue_depth(self.workers, self.queue_depth))
    }

    pub fn predictor(&self) -> &HybridPredictor {
        self.predictor.as_ref()
    }

    /// Get or build the FP32 origin trace for a zoo model (memoized).
    /// The tracker profiles FP32 — the paper measures FP32 and *predicts*
    /// AMP (§6.1.2). The compiled plan rides along in the same cache
    /// entry (a cold key pays one plan build even if the caller only
    /// needs the trace — a fraction of the tracking pass it follows, and
    /// it makes every later evaluation of that key lock-free).
    pub fn trace(&self, model: &str, batch: usize, origin: Device) -> Result<Arc<Trace>> {
        Ok(self.analyzed(model, batch, origin)?.trace)
    }

    /// Get or build a trace tracked at an explicit precision (memoized).
    pub fn trace_with_precision(
        &self,
        model: &str,
        batch: usize,
        origin: Device,
        precision: Precision,
    ) -> Result<Arc<Trace>> {
        Ok(self
            .analyzed_with_precision(model, batch, origin, precision)?
            .trace)
    }

    /// Get or build the FP32 trace **and** its compiled plan (memoized
    /// together — one tracking pass, one analysis pass per key).
    pub fn analyzed(&self, model: &str, batch: usize, origin: Device) -> Result<AnalyzedTrace> {
        self.analyzed_with_precision(model, batch, origin, Precision::Fp32)
    }

    /// [`PredictionEngine::analyzed`] at an explicit tracked precision.
    pub fn analyzed_with_precision(
        &self,
        model: &str,
        batch: usize,
        origin: Device,
        precision: Precision,
    ) -> Result<AnalyzedTrace> {
        let key = (model.to_string(), batch, origin, precision);
        // Hit path: a shard read guard and an `Arc` clone — no global
        // lock, so concurrent hits (same key or not) never serialize.
        // Miss path: `claim` hands exactly one caller the build license;
        // a thundering herd of identical cold requests parks on the
        // shard's condvar and wakes into a hit, tracking exactly once.
        match self.entries.claim(&key) {
            Claim::Hit(entry) => {
                self.trace_hits.fetch_add(1, Relaxed);
                Ok(entry)
            }
            Claim::Build(license) => {
                // An LRU-evicted key may still sit in the persistent
                // store: restoring it skips the whole tracking +
                // compilation pipeline and is bit-identical to it.
                if let Some(store) = &self.store {
                    if let Some(entry) = store
                        .lookup(&key)
                        .and_then(|id| store.load(&id))
                        .map(|(_, entry)| entry)
                    {
                        self.store_hits.fetch_add(1, Relaxed);
                        license.complete(entry.clone());
                        return Ok(entry);
                    }
                }
                let Some(graph) = models::by_name(model, batch) else {
                    // Dropping the license releases the gate (waiters
                    // retry and fail the same way) — an unknown model is
                    // an error, not a miss.
                    anyhow::bail!("unknown model {model:?}");
                };
                // Count a miss only when the tracking pipeline actually
                // runs; track outside every lock.
                self.trace_misses.fetch_add(1, Relaxed);
                if self.store.is_some() {
                    self.store_misses.fetch_add(1, Relaxed);
                }
                self.plan_builds.fetch_add(1, Relaxed);
                let trace = Arc::new(
                    OperationTracker::new(origin)
                        .with_precision(precision)
                        .track(&graph),
                );
                let plan = self.compile(&trace);
                let entry = AnalyzedTrace { trace, plan };
                self.persist(StoredKind::Zoo, &entry);
                license.complete(entry.clone());
                Ok(entry)
            }
        }
    }

    /// Compile a plan for an externally supplied trace (e.g. loaded from
    /// a file) with this engine's metrics policy. Not cached — zoo
    /// models should go through [`PredictionEngine::analyzed`] instead.
    pub fn analyze(&self, trace: &Trace) -> Arc<AnalyzedPlan> {
        self.plan_builds.fetch_add(1, Relaxed);
        self.compile(trace)
    }

    /// The one plan-compilation call site: the per-device lane rows fill
    /// on the shared compute pool ([`AnalyzedPlan::build_parallel`] —
    /// work-claiming, so compiling *from* a pool worker still makes
    /// progress), bit-identical to the serial build.
    fn compile(&self, trace: &Trace) -> Arc<AnalyzedPlan> {
        let (plan, chunks) =
            AnalyzedPlan::build_parallel(trace, &self.predictor.metrics_policy, self.pool());
        self.parallel_build_chunks.fetch_add(chunks, Relaxed);
        Arc::new(plan)
    }

    /// Write-behind persistence: offer the save to the compute pool and
    /// fall back to saving inline if the queue is full (`try_execute`
    /// consumes the job on `Busy`, hence the pre-cloned captures). A
    /// failed save only costs a recompile on some future boot, so
    /// errors are deliberately dropped. No-op without a store.
    fn persist(&self, kind: StoredKind, entry: &AnalyzedTrace) {
        let Some(store) = &self.store else { return };
        let job_store = Arc::clone(store);
        let job_entry = entry.clone();
        if self
            .pool()
            .try_execute(move || {
                let _ = job_store.save(kind, &job_entry);
            })
            .is_err()
        {
            let _ = store.save(kind, entry);
        }
    }

    /// Accept a client-supplied trace (the open-world analogue of the
    /// zoo-model tracking pipeline): analyze it once and retain
    /// trace + plan under a **content-hashed id** (`tr-<16 hex>`), which
    /// [`PredictionEngine::predict_uploaded`] /
    /// [`PredictionEngine::rank_uploaded`] accept in place of
    /// `(model, batch, origin)`. Deterministic and idempotent: the same
    /// trace always maps to the same id, and re-submission reuses the
    /// already-compiled plan.
    pub fn submit_trace(&self, trace: Trace) -> Result<(String, AnalyzedTrace)> {
        anyhow::ensure!(!trace.ops.is_empty(), "trace has no ops");
        anyhow::ensure!(trace.batch_size > 0, "trace batch_size must be positive");
        let canonical = trace.to_json();
        let id = format!("tr-{:016x}", crate::util::rng::hash_str(&canonical));
        // The id is a 64-bit content hash; on any hit, confirm the
        // content actually matches so a collision surfaces as an error
        // instead of silently serving another client's trace.
        if let Some(entry) = self.uploads.get(&id) {
            anyhow::ensure!(
                entry.trace.to_json() == canonical,
                "trace id {id} collides with a different previously submitted trace"
            );
            return Ok((id, entry));
        }
        // Analyze outside every lock: a large plan compile must not
        // block concurrent uploaded-trace predictions or stats reads.
        let entry = AnalyzedTrace {
            plan: self.analyze(&trace),
            trace: Arc::new(trace),
        };
        // One shard write lock decides the winner of an identical
        // concurrent submission race; the upload is counted once.
        let (stored, inserted) = self.uploads.get_or_insert(id.clone(), entry);
        if inserted {
            self.trace_uploads.fetch_add(1, Relaxed);
            self.persist(StoredKind::Upload, &stored);
        } else {
            anyhow::ensure!(
                stored.trace.to_json() == canonical,
                "trace id {id} collides with a different previously submitted trace"
            );
        }
        Ok((id, stored))
    }

    /// Look up a previously submitted trace by id — in the upload
    /// cache first, then (for ids that aged out of the LRU) in the
    /// persistent store.
    pub fn uploaded(&self, trace_id: &str) -> Option<AnalyzedTrace> {
        if let Some(entry) = self.uploads.get(&trace_id.to_string()) {
            return Some(entry);
        }
        let store = self.store.as_ref()?;
        let (kind, entry) = store.load(trace_id)?;
        if kind != StoredKind::Upload {
            return None;
        }
        self.store_hits.fetch_add(1, Relaxed);
        let (stored, _) = self.uploads.get_or_insert(trace_id.to_string(), entry);
        Some(stored)
    }

    fn uploaded_or_err(&self, trace_id: &str) -> Result<AnalyzedTrace> {
        self.uploaded(trace_id).ok_or_else(|| {
            anyhow::anyhow!("unknown trace {trace_id:?} (submit_trace it first — ids may also age out of the upload cache)")
        })
    }

    /// Predict a previously submitted trace onto one destination — the
    /// same plan/evaluate path as a zoo model, so the result is
    /// identical to the equivalent in-process `analyze` + `evaluate`.
    pub fn predict_uploaded(
        &self,
        trace_id: &str,
        dest: Device,
        precision: Precision,
    ) -> Result<EnginePrediction> {
        let analyzed = self.uploaded_or_err(trace_id)?;
        let pred = self.evaluate(&analyzed.plan, dest, precision);
        Ok(EnginePrediction {
            trace: analyzed.trace,
            pred,
        })
    }

    /// Rank destinations for a previously submitted trace.
    pub fn rank_uploaded(
        &self,
        trace_id: &str,
        dests: &[Device],
        precision: Precision,
    ) -> Result<Ranking> {
        anyhow::ensure!(!dests.is_empty(), "rank needs at least one destination");
        let analyzed = self.uploaded_or_err(trace_id)?;
        Ok(self.rank_analyzed(&analyzed, dests, precision))
    }

    /// Predict one `(model, batch, origin) → dest` pair, tracking (or
    /// reusing) the origin trace. `precision` selects the prediction:
    /// FP32 directly, or the AMP transform composed on top (§6.1.2).
    pub fn predict(
        &self,
        model: &str,
        batch: usize,
        origin: Device,
        dest: Device,
        precision: Precision,
    ) -> Result<EnginePrediction> {
        anyhow::ensure!(batch > 0, "batch must be positive");
        let analyzed = self.analyzed(model, batch, origin)?;
        let pred = self.evaluate(&analyzed.plan, dest, precision);
        Ok(EnginePrediction {
            trace: analyzed.trace,
            pred,
        })
    }

    /// Evaluate a compiled plan on one destination: the thin
    /// per-destination loop (pure scaling arithmetic, no locking).
    pub fn evaluate(
        &self,
        plan: &AnalyzedPlan,
        dest: Device,
        precision: Precision,
    ) -> PredictedTrace {
        self.predictor.evaluate_with_precision(plan, dest, precision)
    }

    /// Predict an already-tracked trace onto one destination.
    /// Compatibility path for external traces: compiles a one-off plan
    /// per call — callers with a destination loop should [`Self::analyze`]
    /// once and [`Self::evaluate`] per destination.
    pub fn predict_trace(&self, trace: &Trace, dest: Device, precision: Precision) -> PredictedTrace {
        let plan = self.analyze(trace);
        self.evaluate(&plan, dest, precision)
    }

    /// Evaluate one compiled plan on many destinations with the
    /// kernel-major batched sweep
    /// ([`HybridPredictor::evaluate_batch_with`]): one pass over the
    /// plan's flat kernel arrays accumulates every destination at once,
    /// reusing this thread's pooled scratch arena
    /// ([`pool::with_scratch`]) so steady-state sweeps allocate nothing
    /// beyond the returned traces. Duplicate destinations are evaluated
    /// once and re-expanded. Bit-identical to sequential
    /// [`PredictionEngine::evaluate`] calls.
    pub fn evaluate_batch(
        &self,
        plan: &AnalyzedPlan,
        dests: &[Device],
        precision: Precision,
    ) -> Vec<PredictedTrace> {
        pool::with_scratch(|scratch| {
            self.predictor
                .evaluate_batch_with(plan, dests, precision, scratch)
        })
    }

    /// Evaluate one compiled plan on *all* destinations, cooperatively
    /// with the shared compute pool. Results come back in `dests` order
    /// and are bit-identical to sequential [`PredictionEngine::evaluate`]
    /// calls.
    ///
    /// The destination set is first **deduped** (each unique destination
    /// evaluated once, results re-expanded to the caller's order), then
    /// split into chunks of at least [`Self::FAN_OUT_MIN_CHUNK`] unique
    /// destinations; each chunk is one kernel-major batched sweep
    /// ([`PredictionEngine::evaluate_batch`]) on a pooled scratch arena,
    /// so helpers amortize the plan walk across their whole chunk
    /// instead of re-walking it per destination.
    ///
    /// Scheduling is **work-claiming**: chunks sit behind an atomic
    /// cursor, helper jobs are offered to the pool with a non-blocking
    /// [`pool::WorkerPool::try_execute`], and the calling thread claims
    /// work too. The call therefore completes even if the pool
    /// contributes zero helpers — which makes it safe to fan out *from
    /// inside* a pool worker (every service `rank` does), with no risk
    /// of the workers deadlocking on each other.
    pub fn fan_out(
        &self,
        plan: &Arc<AnalyzedPlan>,
        dests: &[Device],
        precision: Precision,
    ) -> Vec<PredictedTrace> {
        if dests.is_empty() {
            return Vec::new();
        }
        // Dedup before dispatch (linear scan: destination sets are
        // small). `slot[i]` maps caller position i to its unique slot.
        let mut uniq: Vec<Device> = Vec::with_capacity(dests.len());
        let mut slot: Vec<usize> = Vec::with_capacity(dests.len());
        for &d in dests {
            match uniq.iter().position(|&u| u == d) {
                Some(i) => slot.push(i),
                None => {
                    slot.push(uniq.len());
                    uniq.push(d);
                }
            }
        }

        let n_chunks = uniq
            .len()
            .div_ceil(Self::FAN_OUT_MIN_CHUNK)
            .min(self.workers())
            .max(1);
        let uniq_preds = if n_chunks == 1 {
            // Small sets (or a single worker): one sweep on the calling
            // thread covers everything — still batched, still scratch-
            // pooled, no channel round-trip.
            self.evaluate_batch(plan, &uniq, precision)
        } else {
            self.fan_out_chunked(plan, &uniq, precision, n_chunks)
        };

        if uniq.len() == dests.len() {
            return uniq_preds;
        }
        slot.into_iter().map(|i| uniq_preds[i].clone()).collect()
    }

    /// Smallest number of unique destinations worth a separate fan-out
    /// chunk: below this, the per-chunk channel + scheduling overhead
    /// outweighs the batched sweep it would offload.
    pub const FAN_OUT_MIN_CHUNK: usize = 4;

    /// The multi-chunk fan-out path: work-claiming over chunk indices,
    /// each chunk one batched sweep. Chunk results travel back as
    /// `thread::Result` so a panicking evaluation (e.g. a misbehaving
    /// external MLP backend) re-raises its original payload in the
    /// caller instead of surfacing as an opaque missing result.
    fn fan_out_chunked(
        &self,
        plan: &Arc<AnalyzedPlan>,
        uniq: &[Device],
        precision: Precision,
        n_chunks: usize,
    ) -> Vec<PredictedTrace> {
        struct BatchedFanOut {
            plan: Arc<AnalyzedPlan>,
            predictor: Arc<HybridPredictor>,
            dests: Vec<Device>,
            chunk: usize,
            n_chunks: usize,
            precision: Precision,
            next: AtomicUsize,
            tx: mpsc::Sender<(usize, std::thread::Result<Vec<PredictedTrace>>)>,
        }
        impl BatchedFanOut {
            fn run(&self) {
                loop {
                    let c = self.next.fetch_add(1, Relaxed);
                    if c >= self.n_chunks {
                        break;
                    }
                    // Uneven division can leave a trailing chunk empty;
                    // clamp so the slice stays valid (an empty sweep is
                    // a no-op and the caller expects no entries from it).
                    let start = (c * self.chunk).min(self.dests.len());
                    let end = (start + self.chunk).min(self.dests.len());
                    let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                        pool::with_scratch(|scratch| {
                            self.predictor.evaluate_batch_with(
                                &self.plan,
                                &self.dests[start..end],
                                self.precision,
                                scratch,
                            )
                        })
                    }));
                    if self.tx.send((start, result)).is_err() {
                        break; // the caller bailed (panic propagation)
                    }
                }
            }
        }
        let (tx, rx) = mpsc::channel();
        let shared = Arc::new(BatchedFanOut {
            plan: Arc::clone(plan),
            predictor: Arc::clone(&self.predictor),
            dests: uniq.to_vec(),
            chunk: uniq.len().div_ceil(n_chunks),
            n_chunks,
            precision,
            next: AtomicUsize::new(0),
            tx,
        });
        for _ in 0..n_chunks - 1 {
            let state = Arc::clone(&shared);
            if self.pool().try_execute(move || state.run()).is_err() {
                break; // pool saturated: the caller covers the rest alone
            }
        }
        shared.run();
        drop(shared);
        let mut out: Vec<Option<PredictedTrace>> = Vec::with_capacity(uniq.len());
        out.resize_with(uniq.len(), || None);
        for _ in 0..n_chunks {
            let (start, result) = rx.recv().expect("a fan-out participant vanished");
            match result {
                Ok(preds) => {
                    for (j, pred) in preds.into_iter().enumerate() {
                        out[start + j] = Some(pred);
                    }
                }
                Err(payload) => std::panic::resume_unwind(payload),
            }
        }
        out.into_iter()
            .map(|p| p.expect("every destination predicted"))
            .collect()
    }

    /// Run every job of a multi-trace sweep through one work-claimed
    /// job set on the shared pool: jobs sit behind an atomic cursor,
    /// helpers are offered once with a non-blocking
    /// [`pool::WorkerPool::try_execute`], and the calling thread claims
    /// jobs too (deadlock-free from inside a pool worker, like
    /// [`PredictionEngine::fan_out`]). Each claimed job is one batched
    /// sweep on the claimer's pooled scratch — no per-job pool
    /// round-trip, no cross-job barrier. With one worker (or one job)
    /// everything runs on the calling thread with no channel at all.
    /// `eval` maps one `(plan, dests, precision)` job to its result;
    /// results come back in job order; a panicking job re-raises its
    /// payload in the caller.
    fn sweep_many<T, F>(&self, jobs: &[SweepJob<'_>], eval: F) -> Vec<T>
    where
        T: Send + 'static,
        F: Fn(&HybridPredictor, &AnalyzedPlan, &[Device], Precision) -> T
            + Send
            + Sync
            + 'static,
    {
        let n_claimers = self.workers().min(jobs.len()).max(1);
        if n_claimers == 1 {
            return jobs
                .iter()
                .map(|j| eval(&self.predictor, &j.plan, j.dests, j.precision))
                .collect();
        }

        struct ManySweeps<T, F> {
            predictor: Arc<HybridPredictor>,
            jobs: Vec<(Arc<AnalyzedPlan>, Vec<Device>, Precision)>,
            eval: F,
            next: AtomicUsize,
            tx: mpsc::Sender<(usize, std::thread::Result<T>)>,
        }
        impl<T, F> ManySweeps<T, F>
        where
            F: Fn(&HybridPredictor, &AnalyzedPlan, &[Device], Precision) -> T,
        {
            fn run(&self) {
                loop {
                    let j = self.next.fetch_add(1, Relaxed);
                    let Some((plan, dests, precision)) = self.jobs.get(j) else {
                        break;
                    };
                    let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                        (self.eval)(&self.predictor, plan, dests, *precision)
                    }));
                    if self.tx.send((j, result)).is_err() {
                        break; // the caller bailed (panic propagation)
                    }
                }
            }
        }

        let (tx, rx) = mpsc::channel();
        let shared = Arc::new(ManySweeps {
            predictor: Arc::clone(&self.predictor),
            jobs: jobs
                .iter()
                .map(|j| (Arc::clone(&j.plan), j.dests.to_vec(), j.precision))
                .collect(),
            eval,
            next: AtomicUsize::new(0),
            tx,
        });
        for _ in 0..n_claimers - 1 {
            let state = Arc::clone(&shared);
            if self.pool().try_execute(move || state.run()).is_err() {
                break; // pool saturated: the caller covers the rest alone
            }
        }
        shared.run();
        drop(shared);
        let mut out: Vec<Option<T>> = Vec::with_capacity(jobs.len());
        out.resize_with(jobs.len(), || None);
        for _ in 0..jobs.len() {
            let (j, result) = rx.recv().expect("a multi-sweep participant vanished");
            match result {
                Ok(v) => out[j] = Some(v),
                Err(payload) => std::panic::resume_unwind(payload),
            }
        }
        out.into_iter().map(|v| v.expect("every job swept")).collect()
    }

    /// Evaluate many `(plan, destination set)` pairs in one call: all
    /// sweeps are scheduled as a single work-claimed job set on the
    /// shared pool (one scratch per claiming thread, no per-call pool
    /// round-trips). Results come back in job order, each a
    /// `Vec<PredictedTrace>` in that job's destination order,
    /// bit-identical to calling [`PredictionEngine::evaluate_batch`]
    /// per job.
    pub fn evaluate_many(&self, jobs: &[SweepJob<'_>]) -> Vec<Vec<PredictedTrace>> {
        self.sweep_many(jobs, |predictor, plan, dests, precision| {
            pool::with_scratch(|scratch| {
                predictor.evaluate_batch_with(plan, dests, precision, scratch)
            })
        })
    }

    /// The aggregate-only multi-trace sweep: like
    /// [`PredictionEngine::evaluate_many`] but leaving only each
    /// destination's predicted iteration time (ms) in `out`, without
    /// materializing any [`PredictedTrace`]. The cluster throughput
    /// matrix and the dp/scheduler experiments run on this path. With a
    /// warm `out` arena and a single worker, steady-state calls perform
    /// **zero heap allocation** (pinned by
    /// `rust/tests/batched_alloc.rs`); the multi-worker path pays only
    /// the job-set channel, never per-destination allocations.
    pub fn evaluate_many_times(&self, jobs: &[SweepJob<'_>], out: &mut SweepTimes) {
        out.reset(jobs);
        let n_claimers = self.workers().min(jobs.len()).max(1);
        if n_claimers == 1 {
            // Serial: sweep straight into the caller's arena — no
            // channel, no per-job result vectors.
            for (j, job) in jobs.iter().enumerate() {
                let start = out.offsets[j];
                pool::with_scratch(|scratch| {
                    self.predictor.evaluate_batch_times(
                        &job.plan,
                        job.dests,
                        job.precision,
                        scratch,
                    );
                    for i in 0..job.dests.len() {
                        out.times[start + i] = scratch.run_time_ms(i);
                    }
                });
            }
            return;
        }
        let rows = self.sweep_many(jobs, |predictor, plan, dests, precision| {
            pool::with_scratch(|scratch| {
                predictor.evaluate_batch_times(plan, dests, precision, scratch);
                (0..dests.len()).map(|i| scratch.run_time_ms(i)).collect::<Vec<f64>>()
            })
        });
        for (j, row) in rows.into_iter().enumerate() {
            let start = out.offsets[j];
            out.times[start..start + row.len()].copy_from_slice(&row);
        }
    }

    /// Rank many `(model, batch, origin)` traces against one shared
    /// destination set in a single call: every origin is tracked +
    /// analyzed (or reused) through the cache, all sweeps run as one
    /// work-claimed job set ([`PredictionEngine::evaluate_many`]), and
    /// each trace's destinations are ordered exactly as
    /// [`PredictionEngine::rank`] orders them — one result row per
    /// item, in item order. A whole model zoo × registry ranking is one
    /// call (and, over the wire, one `rank_many` request).
    pub fn rank_many(
        &self,
        items: &[RankManyItem],
        dests: &[Device],
        precision: Precision,
    ) -> Result<Vec<Ranking>> {
        anyhow::ensure!(!items.is_empty(), "rank_many needs at least one item");
        anyhow::ensure!(!dests.is_empty(), "rank_many needs at least one destination");
        for item in items {
            anyhow::ensure!(item.batch > 0, "batch must be positive");
        }
        let analyzed = items
            .iter()
            .map(|item| self.analyzed(&item.model, item.batch, item.origin))
            .collect::<Result<Vec<_>>>()?;
        let jobs: Vec<SweepJob<'_>> = analyzed
            .iter()
            .map(|a| SweepJob {
                plan: Arc::clone(&a.plan),
                dests,
                precision,
            })
            .collect();
        let preds = self.evaluate_many(&jobs);
        Ok(analyzed
            .iter()
            .zip(preds)
            .map(|(a, preds)| Self::ranking(a, dests, preds))
            .collect())
    }

    /// The paper's Fig. 1 decision as one call: track + analyze (or
    /// reuse) the origin once, fan out to every destination on the
    /// persistent pool, and rank by cost-normalized throughput. Rentable
    /// devices come first in descending samples/s/$; devices without a
    /// rental price follow, ordered by raw throughput. Ties keep `dests`
    /// order.
    pub fn rank(
        &self,
        model: &str,
        batch: usize,
        origin: Device,
        dests: &[Device],
        precision: Precision,
    ) -> Result<Ranking> {
        anyhow::ensure!(batch > 0, "batch must be positive");
        anyhow::ensure!(!dests.is_empty(), "rank needs at least one destination");
        let analyzed = self.analyzed(model, batch, origin)?;
        Ok(self.rank_analyzed(&analyzed, dests, precision))
    }

    /// Fan out one analyzed trace and sort by cost-normalized
    /// throughput — shared by the zoo-model and uploaded-trace ranks.
    fn rank_analyzed(
        &self,
        analyzed: &AnalyzedTrace,
        dests: &[Device],
        precision: Precision,
    ) -> Ranking {
        let preds = self.fan_out(&analyzed.plan, dests, precision);
        Self::ranking(analyzed, dests, preds)
    }

    /// Build one sorted [`Ranking`] from already-evaluated destination
    /// predictions — the single entry-construction + ordering used by
    /// [`PredictionEngine::rank`] and [`PredictionEngine::rank_many`],
    /// so the two cannot drift.
    fn ranking(analyzed: &AnalyzedTrace, dests: &[Device], preds: Vec<PredictedTrace>) -> Ranking {
        let mut entries: Vec<RankEntry> = dests
            .iter()
            .zip(preds)
            .map(|(&dest, pred)| {
                let cnt = cost::cost_normalized_throughput(dest, pred.throughput());
                RankEntry {
                    dest,
                    pred,
                    cost_normalized_throughput: cnt,
                }
            })
            .collect();
        entries.sort_by(|a, b| {
            rank_order(
                (a.cost_normalized_throughput, a.pred.throughput()),
                (b.cost_normalized_throughput, b.pred.throughput()),
            )
        });
        Ranking {
            trace: Arc::clone(&analyzed.trace),
            entries,
        }
    }

    /// Predict one `(model, batch, origin) → dest` pair across a whole
    /// topology × world-size grid in one call: the single-GPU compute
    /// time is evaluated once (Habitat's job), the trace's gradient
    /// volume and backward share are derived once, and each
    /// `(topology, world)` cell composes them with the bucketed
    /// hierarchical allreduce model ([`comm::cluster::compose`]).
    /// `world == 1` cells carry zero communication, so their `iter_ms`
    /// is bit-identical to [`PredictionEngine::predict`].
    #[allow(clippy::too_many_arguments)]
    pub fn predict_cluster(
        &self,
        model: &str,
        batch: usize,
        origin: Device,
        dest: Device,
        precision: Precision,
        topologies: &[Topology],
        worlds: &[usize],
        params: &ClusterParams,
    ) -> Result<ClusterReport> {
        anyhow::ensure!(batch > 0, "batch must be positive");
        let analyzed = self.analyzed(model, batch, origin)?;
        self.cluster_report(&analyzed, dest, precision, topologies, worlds, params)
    }

    /// [`PredictionEngine::predict_cluster`] for several `(model, batch)`
    /// pairs at once: every model's single-GPU compute time comes from
    /// **one** multi-trace sweep on the shared pool
    /// ([`PredictionEngine::evaluate_many_times`]), then each report's
    /// topology × world grid composes exactly as in
    /// [`PredictionEngine::predict_cluster`] — reports are bit-identical
    /// to the per-model calls, in input order.
    #[allow(clippy::too_many_arguments)]
    pub fn predict_cluster_many(
        &self,
        items: &[(&str, usize)],
        origin: Device,
        dest: Device,
        precision: Precision,
        topologies: &[Topology],
        worlds: &[usize],
        params: &ClusterParams,
    ) -> Result<Vec<ClusterReport>> {
        Self::check_cluster_grid(topologies, worlds)?;
        anyhow::ensure!(!items.is_empty(), "cluster sweep needs at least one model");
        let analyzed: Vec<AnalyzedTrace> = items
            .iter()
            .map(|(model, batch)| {
                anyhow::ensure!(*batch > 0, "batch must be positive");
                self.analyzed(model, *batch, origin)
            })
            .collect::<Result<_>>()?;
        let dests = [dest];
        let jobs: Vec<SweepJob<'_>> = analyzed
            .iter()
            .map(|a| SweepJob {
                plan: Arc::clone(&a.plan),
                dests: &dests,
                precision,
            })
            .collect();
        let mut times = SweepTimes::new();
        self.evaluate_many_times(&jobs, &mut times);
        Ok(analyzed
            .iter()
            .enumerate()
            .map(|(i, a)| Self::compose_report(a, dest, times.job(i)[0], topologies, worlds, params))
            .collect())
    }

    /// [`PredictionEngine::predict_cluster`] for a previously submitted
    /// trace.
    pub fn predict_cluster_uploaded(
        &self,
        trace_id: &str,
        dest: Device,
        precision: Precision,
        topologies: &[Topology],
        worlds: &[usize],
        params: &ClusterParams,
    ) -> Result<ClusterReport> {
        let analyzed = self.uploaded_or_err(trace_id)?;
        self.cluster_report(&analyzed, dest, precision, topologies, worlds, params)
    }

    fn check_cluster_grid(topologies: &[Topology], worlds: &[usize]) -> Result<()> {
        anyhow::ensure!(!topologies.is_empty(), "cluster sweep needs at least one topology");
        anyhow::ensure!(!worlds.is_empty(), "cluster sweep needs at least one world size");
        anyhow::ensure!(
            worlds.iter().all(|&w| w >= 1),
            "world sizes must be at least 1"
        );
        Ok(())
    }

    fn cluster_report(
        &self,
        analyzed: &AnalyzedTrace,
        dest: Device,
        precision: Precision,
        topologies: &[Topology],
        worlds: &[usize],
        params: &ClusterParams,
    ) -> Result<ClusterReport> {
        Self::check_cluster_grid(topologies, worlds)?;
        let pred = self.evaluate(&analyzed.plan, dest, precision);
        Ok(Self::compose_report(analyzed, dest, pred.run_time_ms(), topologies, worlds, params))
    }

    /// The grid-composition epilogue shared by [`PredictionEngine::predict_cluster`]
    /// and [`PredictionEngine::predict_cluster_many`]: one already-swept
    /// single-GPU compute time, composed per `(topology, world)` cell.
    fn compose_report(
        analyzed: &AnalyzedTrace,
        dest: Device,
        compute_ms: f64,
        topologies: &[Topology],
        worlds: &[usize],
        params: &ClusterParams,
    ) -> ClusterReport {
        let tc = comm::trace_comm(&analyzed.trace);
        let batch = analyzed.plan.batch_size;
        let mut configs = Vec::with_capacity(topologies.len() * worlds.len());
        for &topology in topologies {
            for &world in worlds {
                let cell = comm::cluster::compose(compute_ms, batch, &tc, topology, world, params);
                configs.push(ClusterCell {
                    topology,
                    world,
                    cost_normalized_throughput: cost::cluster_cost_normalized_throughput(
                        dest,
                        world,
                        cell.throughput,
                    ),
                    pred: cell,
                });
            }
        }
        ClusterReport {
            trace: Arc::clone(&analyzed.trace),
            dest,
            compute_ms,
            configs,
        }
    }

    /// Rank every `(destination, topology, world)` configuration of a
    /// cluster sweep in one call. All destinations' compute times come
    /// from **one** kernel-major batched evaluation
    /// ([`PredictionEngine::evaluate_batch`]); the collective model then
    /// composes each cell, and the result is sorted like
    /// [`PredictionEngine::rank`] — priced fleets first by descending
    /// cost-normalized global throughput, unpriced after by raw global
    /// throughput.
    #[allow(clippy::too_many_arguments)]
    pub fn rank_cluster(
        &self,
        model: &str,
        batch: usize,
        origin: Device,
        dests: &[Device],
        precision: Precision,
        topologies: &[Topology],
        worlds: &[usize],
        params: &ClusterParams,
    ) -> Result<ClusterRanking> {
        anyhow::ensure!(batch > 0, "batch must be positive");
        let analyzed = self.analyzed(model, batch, origin)?;
        self.rank_cluster_analyzed(&analyzed, dests, precision, topologies, worlds, params)
    }

    /// [`PredictionEngine::rank_cluster`] for a previously submitted
    /// trace.
    pub fn rank_cluster_uploaded(
        &self,
        trace_id: &str,
        dests: &[Device],
        precision: Precision,
        topologies: &[Topology],
        worlds: &[usize],
        params: &ClusterParams,
    ) -> Result<ClusterRanking> {
        let analyzed = self.uploaded_or_err(trace_id)?;
        self.rank_cluster_analyzed(&analyzed, dests, precision, topologies, worlds, params)
    }

    fn rank_cluster_analyzed(
        &self,
        analyzed: &AnalyzedTrace,
        dests: &[Device],
        precision: Precision,
        topologies: &[Topology],
        worlds: &[usize],
        params: &ClusterParams,
    ) -> Result<ClusterRanking> {
        anyhow::ensure!(!dests.is_empty(), "rank_cluster needs at least one destination");
        Self::check_cluster_grid(topologies, worlds)?;
        let preds = self.evaluate_batch(&analyzed.plan, dests, precision);
        let tc = comm::trace_comm(&analyzed.trace);
        let batch = analyzed.plan.batch_size;
        let mut entries = Vec::with_capacity(dests.len() * topologies.len() * worlds.len());
        for (&dest, pred) in dests.iter().zip(&preds) {
            let compute_ms = pred.run_time_ms();
            for &topology in topologies {
                for &world in worlds {
                    let cell =
                        comm::cluster::compose(compute_ms, batch, &tc, topology, world, params);
                    entries.push(ClusterRankEntry {
                        dest,
                        topology,
                        world,
                        cost_normalized_throughput: cost::cluster_cost_normalized_throughput(
                            dest,
                            world,
                            cell.throughput,
                        ),
                        pred: cell,
                    });
                }
            }
        }
        entries.sort_by(|a, b| {
            rank_order(
                (a.cost_normalized_throughput, a.pred.throughput),
                (b.cost_normalized_throughput, b.pred.throughput),
            )
        });
        Ok(ClusterRanking {
            trace: Arc::clone(&analyzed.trace),
            entries,
        })
    }

    /// Export the predicted per-step compute + collective schedule for
    /// one cluster configuration as COMM_OPS-style records
    /// ([`comm::Workload`]) — the input format an external network
    /// simulator can replay.
    #[allow(clippy::too_many_arguments)]
    pub fn export_workload(
        &self,
        model: &str,
        batch: usize,
        origin: Device,
        dest: Device,
        precision: Precision,
        topology: Topology,
        world: usize,
        params: &ClusterParams,
    ) -> Result<comm::Workload> {
        anyhow::ensure!(batch > 0, "batch must be positive");
        anyhow::ensure!(world >= 1, "world must be at least 1");
        let analyzed = self.analyzed(model, batch, origin)?;
        let pred = self.evaluate(&analyzed.plan, dest, precision);
        let tc = comm::trace_comm(&analyzed.trace);
        Ok(comm::Workload {
            model: analyzed.trace.model.clone(),
            batch: analyzed.plan.batch_size,
            origin: origin.to_string(),
            dest: dest.to_string(),
            topology: topology.name().to_string(),
            world,
            compute_ms: pred.run_time_ms(),
            comm_ops: comm::comm_schedule(topology, world, tc.grad_bytes, params),
        })
    }

    /// Register a new device through this engine: intern it in the
    /// process-wide registry, then — if it is genuinely new — **extend
    /// every cached plan once** with the device's computed γ/wave/AMP
    /// lane ([`AnalyzedPlan::extend_device`]) so subsequent sweeps read
    /// a precomputed row instead of recomputing inside every
    /// evaluation, and append the registration to the store's durable
    /// device log. Idempotent re-registrations change nothing.
    pub fn register_device(
        &self,
        desc: &NewDevice,
    ) -> std::result::Result<Device, RegisterError> {
        let before = crate::device::registry::device_count();
        let d = crate::device::registry::register(desc)?;
        if d.index() >= before {
            self.entries.for_each(|_, entry| {
                entry.plan.extend_device(d);
            });
            self.uploads.for_each(|_, entry| {
                entry.plan.extend_device(d);
            });
            if let Some(store) = &self.store {
                let _ = store.record_device(desc);
            }
        }
        Ok(d)
    }

    /// Counter snapshot (trace/plan cache + shared wave table + pool).
    /// Entirely lock-free: every counter is an atomic — including the
    /// cache entry counts, which the sharded caches maintain atomically
    /// — so a stats probe never contends with the prediction hot path.
    pub fn stats(&self) -> EngineStats {
        let (wave_hits, wave_misses) = memo::WaveTable::global().counters();
        EngineStats {
            trace_hits: self.trace_hits.load(Relaxed),
            trace_misses: self.trace_misses.load(Relaxed),
            trace_entries: self.entries.len(),
            trace_uploads: self.trace_uploads.load(Relaxed),
            uploaded_entries: self.uploads.len(),
            devices: crate::device::registry::device_count(),
            plan_builds: self.plan_builds.load(Relaxed),
            wave_hits,
            wave_misses,
            workers: self.workers(),
            store_hits: self.store_hits.load(Relaxed),
            store_misses: self.store_misses.load(Relaxed),
            warm_restores: self.warm_restores.load(Relaxed),
            parallel_build_chunks: self.parallel_build_chunks.load(Relaxed),
            requests: self.metrics.requests_total(),
            request_errors: self.metrics.errors_total(),
            simd: crate::util::simdf64::backend(),
        }
    }

    /// The per-op wire-request metrics fed by the coordinator
    /// dispatcher (every engine has them; they stay zero unless the
    /// engine is served over the wire).
    pub fn metrics(&self) -> &metrics::ServiceMetrics {
        &self.metrics
    }

    /// Drop every cached trace+plan entry (the counters are preserved).
    /// Used by the cold-path benches.
    pub fn clear_trace_cache(&self) {
        self.entries.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::ALL_DEVICES;

    fn engine() -> PredictionEngine {
        PredictionEngine::wave_only()
    }

    #[test]
    fn trace_cache_hits_and_counts() {
        let e = engine();
        let a = e.trace("mlp", 16, Device::T4).unwrap();
        let b = e.trace("mlp", 16, Device::T4).unwrap();
        assert!(Arc::ptr_eq(&a, &b), "second lookup must hit the cache");
        let s = e.stats();
        assert_eq!(s.trace_misses, 1);
        assert_eq!(s.trace_hits, 1);
        assert_eq!(s.trace_entries, 1);
        assert_eq!(s.plan_builds, 1, "the plan rides the same cache entry");
    }

    #[test]
    fn analyzed_shares_the_plan_with_the_trace_entry() {
        let e = engine();
        let a = e.analyzed("mlp", 16, Device::T4).unwrap();
        let b = e.analyzed("mlp", 16, Device::T4).unwrap();
        assert!(Arc::ptr_eq(&a.trace, &b.trace));
        assert!(Arc::ptr_eq(&a.plan, &b.plan), "plan must be compiled once");
        assert_eq!(e.stats().plan_builds, 1);
    }

    #[test]
    fn distinct_keys_do_not_collide() {
        let e = engine();
        e.trace("mlp", 16, Device::T4).unwrap();
        e.trace("mlp", 32, Device::T4).unwrap();
        e.trace("mlp", 16, Device::V100).unwrap();
        e.trace_with_precision("mlp", 16, Device::T4, Precision::Amp)
            .unwrap();
        let s = e.stats();
        assert_eq!(s.trace_misses, 4);
        assert_eq!(s.trace_entries, 4);
    }

    #[test]
    fn unknown_model_is_an_error_not_a_miss() {
        let e = engine();
        assert!(e.trace("not_a_model", 16, Device::T4).is_err());
        assert_eq!(e.stats().trace_misses, 0);
    }

    #[test]
    fn lru_capacity_bounds_entries() {
        let e = PredictionEngine::with_capacity(HybridPredictor::wave_only(), 2);
        for batch in [1usize, 2, 4] {
            e.trace("mlp", batch, Device::T4).unwrap();
        }
        assert_eq!(e.stats().trace_entries, 2);
        // The least recently used (batch 1) was evicted; re-requesting it
        // re-tracks.
        e.trace("mlp", 1, Device::T4).unwrap();
        assert_eq!(e.stats().trace_misses, 4);
    }

    #[test]
    fn concurrent_identical_requests_track_once() {
        let e = engine();
        std::thread::scope(|s| {
            for _ in 0..8 {
                s.spawn(|| e.trace("mlp", 16, Device::T4).unwrap());
            }
        });
        let st = e.stats();
        assert_eq!(st.trace_misses, 1, "a thundering herd must track exactly once");
        assert_eq!(st.trace_hits, 7);
        assert_eq!(st.plan_builds, 1, "…and analyze exactly once");
    }

    #[test]
    fn fan_out_matches_sequential_predictions() {
        let e = engine();
        let at = e.analyzed("mlp", 32, Device::T4).unwrap();
        let fanned = e.fan_out(&at.plan, &ALL_DEVICES, Precision::Fp32);
        assert_eq!(fanned.len(), ALL_DEVICES.len());
        for (dest, pred) in ALL_DEVICES.iter().zip(&fanned) {
            assert_eq!(pred.dest, *dest, "results must come back in dests order");
            let seq = e.evaluate(&at.plan, *dest, Precision::Fp32);
            assert_eq!(
                pred.run_time_ms(),
                seq.run_time_ms(),
                "{dest}: fan-out must be bit-identical to sequential"
            );
        }
    }

    #[test]
    fn fan_out_amp_matches_sequential() {
        let e = engine();
        let at = e.analyzed("mlp", 32, Device::P4000).unwrap();
        let dests = [Device::V100, Device::Rtx2080Ti];
        let fanned = e.fan_out(&at.plan, &dests, Precision::Amp);
        for (dest, pred) in dests.iter().zip(&fanned) {
            let seq = e.evaluate(&at.plan, *dest, Precision::Amp);
            assert_eq!(pred.run_time_ms(), seq.run_time_ms());
        }
    }

    #[test]
    fn fan_out_dedups_duplicate_destinations() {
        let e = engine();
        let at = e.analyzed("mlp", 16, Device::T4).unwrap();
        // More caller positions than unique destinations, interleaved,
        // enough to clear the chunked-dispatch threshold when cycled.
        let dests: Vec<Device> = ALL_DEVICES
            .iter()
            .copied()
            .cycle()
            .take(3 * ALL_DEVICES.len())
            .collect();
        for precision in [Precision::Fp32, Precision::Amp] {
            let fanned = e.fan_out(&at.plan, &dests, precision);
            assert_eq!(fanned.len(), dests.len(), "re-expanded to caller order");
            for (d, p) in dests.iter().zip(&fanned) {
                assert_eq!(p.dest, *d);
                let seq = e.evaluate(&at.plan, *d, precision);
                assert_eq!(
                    p.run_time_ms().to_bits(),
                    seq.run_time_ms().to_bits(),
                    "{d} {precision:?}: duplicated fan-out must stay bit-identical"
                );
            }
        }
    }

    #[test]
    fn engine_evaluate_batch_matches_scalar_evaluate() {
        let e = engine();
        let at = e.analyzed("mlp", 32, Device::T4).unwrap();
        for precision in [Precision::Fp32, Precision::Amp] {
            let batch = e.evaluate_batch(&at.plan, &ALL_DEVICES, precision);
            assert_eq!(batch.len(), ALL_DEVICES.len());
            for (d, p) in ALL_DEVICES.iter().zip(&batch) {
                assert_eq!(p.dest, *d);
                let seq = e.evaluate(&at.plan, *d, precision);
                assert_eq!(
                    p.run_time_ms().to_bits(),
                    seq.run_time_ms().to_bits(),
                    "{d} {precision:?}"
                );
            }
        }
    }

    #[test]
    fn evaluate_many_matches_per_job_batches() {
        let e = PredictionEngine::wave_only().with_workers(4);
        let jobs_spec = [
            ("mlp", 16, Device::T4, Precision::Fp32),
            ("mlp", 32, Device::T4, Precision::Amp),
            ("dcgan", 16, Device::P4000, Precision::Fp32),
        ];
        let analyzed: Vec<_> = jobs_spec
            .iter()
            .map(|&(m, b, o, _)| e.analyzed(m, b, o).unwrap())
            .collect();
        let jobs: Vec<SweepJob<'_>> = analyzed
            .iter()
            .zip(&jobs_spec)
            .map(|(a, &(_, _, _, precision))| SweepJob {
                plan: Arc::clone(&a.plan),
                dests: &ALL_DEVICES,
                precision,
            })
            .collect();
        let many = e.evaluate_many(&jobs);
        assert_eq!(many.len(), jobs.len());
        for ((job, a), preds) in jobs.iter().zip(&analyzed).zip(&many) {
            let solo = e.evaluate_batch(&a.plan, job.dests, job.precision);
            assert_eq!(preds.len(), solo.len());
            for (p, s) in preds.iter().zip(&solo) {
                assert_eq!(p.dest, s.dest);
                assert_eq!(
                    p.run_time_ms().to_bits(),
                    s.run_time_ms().to_bits(),
                    "{}: one-call sweep must match the per-job batch",
                    p.dest
                );
            }
        }
    }

    #[test]
    fn evaluate_many_times_matches_materialized_predictions() {
        // Both the serial (1 worker) and the work-claimed (4 workers)
        // paths must leave the exact run times the materializing sweep
        // reports.
        for workers in [1, 4] {
            let e = PredictionEngine::wave_only().with_workers(workers);
            let a = e.analyzed("mlp", 16, Device::T4).unwrap();
            let b = e.analyzed("mlp", 24, Device::T4).unwrap();
            let jobs = [
                SweepJob {
                    plan: Arc::clone(&a.plan),
                    dests: &ALL_DEVICES,
                    precision: Precision::Fp32,
                },
                SweepJob {
                    plan: Arc::clone(&b.plan),
                    dests: &ALL_DEVICES[..3],
                    precision: Precision::Amp,
                },
            ];
            let mut times = SweepTimes::new();
            e.evaluate_many_times(&jobs, &mut times);
            assert_eq!(times.n_jobs(), jobs.len());
            let preds = e.evaluate_many(&jobs);
            for (j, job) in jobs.iter().enumerate() {
                let row = times.job(j);
                assert_eq!(row.len(), job.dests.len());
                for (i, pred) in preds[j].iter().enumerate() {
                    assert_eq!(
                        row[i].to_bits(),
                        pred.run_time_ms().to_bits(),
                        "job {j} dest {i} ({workers} workers)"
                    );
                }
            }
        }
    }

    #[test]
    fn rank_many_matches_individual_ranks() {
        let e = engine();
        let items = vec![
            RankManyItem {
                model: "mlp".into(),
                batch: 16,
                origin: Device::T4,
            },
            RankManyItem {
                model: "dcgan".into(),
                batch: 16,
                origin: Device::P4000,
            },
        ];
        let many = e.rank_many(&items, &ALL_DEVICES, Precision::Fp32).unwrap();
        assert_eq!(many.len(), items.len());
        for (item, ranking) in items.iter().zip(&many) {
            let solo = e
                .rank(&item.model, item.batch, item.origin, &ALL_DEVICES, Precision::Fp32)
                .unwrap();
            assert_eq!(ranking.entries.len(), solo.entries.len());
            for (m, s) in ranking.entries.iter().zip(&solo.entries) {
                assert_eq!(m.dest, s.dest, "{}: one-call rank order must match", item.model);
                assert_eq!(m.pred.run_time_ms().to_bits(), s.pred.run_time_ms().to_bits());
            }
        }
    }

    #[test]
    fn rank_many_rejects_bad_input() {
        let e = engine();
        let item = |model: &str, batch| RankManyItem {
            model: model.into(),
            batch,
            origin: Device::T4,
        };
        assert!(e.rank_many(&[], &ALL_DEVICES, Precision::Fp32).is_err());
        assert!(e
            .rank_many(&[item("mlp", 8)], &[], Precision::Fp32)
            .is_err());
        assert!(e
            .rank_many(&[item("mlp", 0)], &ALL_DEVICES, Precision::Fp32)
            .is_err());
        assert!(e
            .rank_many(&[item("not_a_model", 8)], &ALL_DEVICES, Precision::Fp32)
            .is_err());
    }

    #[test]
    fn predict_cluster_many_matches_per_model_reports() {
        let e = engine();
        let items = [("mlp", 16usize), ("dcgan", 16)];
        let topologies = [Topology::DGX, Topology::CLOUD];
        let worlds = [1usize, 4];
        let params = ClusterParams::default();
        let many = e
            .predict_cluster_many(
                &items,
                Device::T4,
                Device::V100,
                Precision::Fp32,
                &topologies,
                &worlds,
                &params,
            )
            .unwrap();
        assert_eq!(many.len(), items.len());
        for ((model, batch), report) in items.iter().zip(&many) {
            let solo = e
                .predict_cluster(
                    model,
                    *batch,
                    Device::T4,
                    Device::V100,
                    Precision::Fp32,
                    &topologies,
                    &worlds,
                    &params,
                )
                .unwrap();
            assert_eq!(report.compute_ms.to_bits(), solo.compute_ms.to_bits());
            assert_eq!(report.configs.len(), solo.configs.len());
            for (a, b) in report.configs.iter().zip(&solo.configs) {
                assert_eq!((a.topology, a.world), (b.topology, b.world));
                assert_eq!(a.pred.iter_ms.to_bits(), b.pred.iter_ms.to_bits());
                assert_eq!(a.pred.throughput.to_bits(), b.pred.throughput.to_bits());
            }
        }
        assert!(e
            .predict_cluster_many(
                &[],
                Device::T4,
                Device::V100,
                Precision::Fp32,
                &topologies,
                &worlds,
                &params,
            )
            .is_err());
    }

    #[test]
    fn stats_report_the_simd_backend() {
        let s = engine().stats();
        assert_eq!(s.simd, crate::util::simdf64::backend());
        assert!(matches!(s.simd, "avx2" | "scalar"));
    }

    #[test]
    fn fan_out_single_worker_still_covers_all() {
        let e = PredictionEngine::wave_only().with_workers(1);
        assert_eq!(e.workers(), 1);
        let at = e.analyzed("mlp", 8, Device::T4).unwrap();
        let fanned = e.fan_out(&at.plan, &ALL_DEVICES, Precision::Fp32);
        assert_eq!(fanned.len(), ALL_DEVICES.len());
    }

    #[test]
    fn pool_is_reused_across_fan_outs() {
        let e = PredictionEngine::wave_only().with_workers(3);
        let at = e.analyzed("mlp", 8, Device::T4).unwrap();
        for _ in 0..4 {
            let fanned = e.fan_out(&at.plan, &ALL_DEVICES, Precision::Fp32);
            assert_eq!(fanned.len(), ALL_DEVICES.len());
        }
        assert_eq!(e.stats().workers, 3, "pool persists across calls");
    }

    #[test]
    fn predict_trace_compat_path_matches_cached_plan_path() {
        let e = engine();
        let at = e.analyzed("mlp", 16, Device::T4).unwrap();
        let builds = e.stats().plan_builds;
        let compat = e.predict_trace(&at.trace, Device::V100, Precision::Fp32);
        let cached = e.evaluate(&at.plan, Device::V100, Precision::Fp32);
        assert_eq!(compat.run_time_ms().to_bits(), cached.run_time_ms().to_bits());
        assert_eq!(
            e.stats().plan_builds,
            builds + 1,
            "predict_trace compiles a one-off plan"
        );
    }

    #[test]
    fn rank_tracks_once_and_sorts_by_cost_normalized_throughput() {
        let e = engine();
        let ranking = e
            .rank("mlp", 32, Device::T4, &ALL_DEVICES, Precision::Fp32)
            .unwrap();
        assert_eq!(ranking.entries.len(), ALL_DEVICES.len());
        assert_eq!(e.stats().trace_misses, 1, "one tracking pass for the whole ranking");

        // Priced devices first, descending; unpriced after, by throughput.
        let first_unpriced = ranking
            .entries
            .iter()
            .position(|en| en.cost_normalized_throughput.is_none())
            .unwrap_or(ranking.entries.len());
        for en in &ranking.entries[..first_unpriced] {
            assert!(en.cost_normalized_throughput.is_some());
        }
        for en in &ranking.entries[first_unpriced..] {
            assert!(en.cost_normalized_throughput.is_none());
        }
        for pair in ranking.entries[..first_unpriced].windows(2) {
            assert!(
                pair[0].cost_normalized_throughput.unwrap()
                    >= pair[1].cost_normalized_throughput.unwrap()
            );
        }
        for pair in ranking.entries[first_unpriced..].windows(2) {
            assert!(pair[0].pred.throughput() >= pair[1].pred.throughput());
        }
    }

    #[test]
    fn rank_matches_individual_predictions() {
        let e = engine();
        let ranking = e
            .rank("mlp", 16, Device::P4000, &ALL_DEVICES, Precision::Fp32)
            .unwrap();
        for en in &ranking.entries {
            let single = e
                .predict("mlp", 16, Device::P4000, en.dest, Precision::Fp32)
                .unwrap();
            assert!(
                (en.pred.run_time_ms() - single.pred.run_time_ms()).abs() < 1e-12,
                "{}: ranked vs individual prediction",
                en.dest
            );
        }
        // All the individual requests above were cache hits.
        let s = e.stats();
        assert_eq!(s.trace_misses, 1);
        assert_eq!(s.trace_hits as usize, ALL_DEVICES.len());
        assert_eq!(s.plan_builds, 1, "every evaluation reused the one plan");
    }

    #[test]
    fn rank_rejects_bad_input() {
        let e = engine();
        assert!(e.rank("mlp", 0, Device::T4, &ALL_DEVICES, Precision::Fp32).is_err());
        assert!(e.rank("mlp", 8, Device::T4, &[], Precision::Fp32).is_err());
        assert!(e
            .rank("not_a_model", 8, Device::T4, &ALL_DEVICES, Precision::Fp32)
            .is_err());
    }

    #[test]
    fn submit_trace_is_content_keyed_and_idempotent() {
        let e = engine();
        let graph = crate::models::by_name("mlp", 24).unwrap();
        let trace = OperationTracker::new(Device::T4).track(&graph);
        let (id, analyzed) = e.submit_trace(trace.clone()).unwrap();
        assert!(id.starts_with("tr-"), "{id}");
        let (id2, analyzed2) = e.submit_trace(trace).unwrap();
        assert_eq!(id, id2, "same content must map to the same id");
        assert!(Arc::ptr_eq(&analyzed.plan, &analyzed2.plan), "plan compiled once");
        let s = e.stats();
        assert_eq!(s.trace_uploads, 1, "re-submission is not a new upload");
        assert_eq!(s.uploaded_entries, 1);
        assert_eq!(s.plan_builds, 1);

        // A different trace gets a different id.
        let other = OperationTracker::new(Device::T4)
            .track(&crate::models::by_name("mlp", 48).unwrap());
        let (other_id, _) = e.submit_trace(other).unwrap();
        assert_ne!(id, other_id);
    }

    #[test]
    fn uploaded_trace_predictions_match_in_process_evaluation() {
        let e = engine();
        let graph = crate::models::by_name("mlp", 24).unwrap();
        let trace = OperationTracker::new(Device::T4).track(&graph);
        let (id, analyzed) = e.submit_trace(trace).unwrap();

        let up = e.predict_uploaded(&id, Device::V100, Precision::Fp32).unwrap();
        let direct = e.evaluate(&analyzed.plan, Device::V100, Precision::Fp32);
        assert_eq!(up.pred.run_time_ms().to_bits(), direct.run_time_ms().to_bits());
        assert!(Arc::ptr_eq(&up.trace, &analyzed.trace));

        let ranking = e.rank_uploaded(&id, &ALL_DEVICES, Precision::Amp).unwrap();
        assert_eq!(ranking.entries.len(), ALL_DEVICES.len());
        for en in &ranking.entries {
            let single = e.predict_uploaded(&id, en.dest, Precision::Amp).unwrap();
            assert_eq!(
                en.pred.run_time_ms().to_bits(),
                single.pred.run_time_ms().to_bits(),
                "{}",
                en.dest
            );
        }
    }

    #[test]
    fn uploaded_trace_errors() {
        let e = engine();
        assert!(e.predict_uploaded("tr-nope", Device::V100, Precision::Fp32).is_err());
        assert!(e.rank_uploaded("tr-nope", &ALL_DEVICES, Precision::Fp32).is_err());
        let empty = Trace {
            model: "empty".into(),
            batch_size: 1,
            origin: Device::T4,
            precision: Precision::Fp32,
            ops: Vec::new(),
        };
        assert!(e.submit_trace(empty).is_err(), "an op-less trace is rejected");
    }

    fn store_dir(tag: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "habitat_engine_store_{tag}_{}",
            std::process::id()
        ));
        std::fs::remove_dir_all(&dir).ok();
        dir
    }

    /// Write-behind saves land on the pool; poll until the expected
    /// number of records is visible (bounded, so a bug fails fast).
    fn await_records(e: &PredictionEngine, n: usize) {
        for _ in 0..500 {
            if e.store().unwrap().ids().len() >= n {
                return;
            }
            std::thread::sleep(std::time::Duration::from_millis(10));
        }
        panic!("store never reached {n} records");
    }

    #[test]
    fn warm_restore_round_trips_zoo_and_uploads() {
        let dir = store_dir("roundtrip");
        let (id, fresh_ms) = {
            let e = PredictionEngine::wave_only().with_store(&dir).unwrap();
            let at = e.analyzed("mlp", 16, Device::T4).unwrap();
            let fresh_ms = e.evaluate(&at.plan, Device::V100, Precision::Amp).run_time_ms();
            let trace = OperationTracker::new(Device::T4)
                .track(&crate::models::by_name("mlp", 24).unwrap());
            let (id, _) = e.submit_trace(trace).unwrap();
            let s = e.stats();
            assert_eq!(s.warm_restores, 0, "nothing on disk yet");
            assert_eq!(s.store_misses, 1, "the zoo compile checked the store");
            assert!(s.parallel_build_chunks >= 2, "lane rows filled in parallel");
            await_records(&e, 2);
            (id, fresh_ms)
            // Dropping the engine joins the pool, flushing any
            // still-queued write-behind saves.
        };

        let e2 = PredictionEngine::wave_only().with_store(&dir).unwrap();
        let s = e2.stats();
        assert_eq!(s.warm_restores, 2, "both records restored at boot");
        assert_eq!(s.trace_entries, 1);
        assert_eq!(s.uploaded_entries, 1);

        // The zoo entry is a plain cache hit — no re-track, no rebuild.
        let at = e2.analyzed("mlp", 16, Device::T4).unwrap();
        let s = e2.stats();
        assert_eq!(s.trace_misses, 0);
        assert_eq!(s.trace_hits, 1);
        assert_eq!(s.plan_builds, 0, "warm restore compiles nothing");
        // …and the restored plan evaluates bit-identically.
        let restored_ms = e2.evaluate(&at.plan, Device::V100, Precision::Amp).run_time_ms();
        assert_eq!(restored_ms.to_bits(), fresh_ms.to_bits());

        // The restored upload serves predictions under its old id.
        assert!(e2.predict_uploaded(&id, Device::V100, Precision::Fp32).is_ok());
        assert_eq!(e2.stats().trace_uploads, 0, "a restore is not a new upload");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn evicted_entries_restore_from_store_without_retracking() {
        let dir = store_dir("evict");
        let e = PredictionEngine::with_capacity(HybridPredictor::wave_only(), 2)
            .with_store(&dir)
            .unwrap();
        for batch in [1usize, 2, 4] {
            e.trace("mlp", batch, Device::T4).unwrap();
        }
        await_records(&e, 3);
        assert_eq!(e.stats().trace_entries, 2, "batch 1 evicted");
        // Re-requesting the evicted key restores it from disk: a store
        // hit, not a fourth tracking pass.
        e.trace("mlp", 1, Device::T4).unwrap();
        let s = e.stats();
        assert_eq!(s.trace_misses, 3);
        assert_eq!(s.store_hits, 1);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn corrupt_store_records_are_rebuilt_transparently() {
        let dir = store_dir("corrupt");
        {
            let e = PredictionEngine::wave_only().with_store(&dir).unwrap();
            e.analyzed("mlp", 16, Device::T4).unwrap();
            await_records(&e, 1);
        }
        // Flip one payload byte in the record.
        let path = std::fs::read_dir(&dir)
            .unwrap()
            .flatten()
            .map(|en| en.path())
            .find(|p| p.extension().is_some_and(|x| x == "plan"))
            .unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        let last = bytes.len() - 1;
        bytes[last] ^= 0xFF;
        std::fs::write(&path, bytes).unwrap();

        let e2 = PredictionEngine::wave_only().with_store(&dir).unwrap();
        let s = e2.stats();
        assert_eq!(s.warm_restores, 0, "a corrupt record must not restore");
        // The model still works — rebuilt from source and re-persisted.
        e2.analyzed("mlp", 16, Device::T4).unwrap();
        assert_eq!(e2.stats().trace_misses, 1);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn register_device_extends_cached_plans_once() {
        let e = engine();
        let at = e.analyzed("mlp", 16, Device::T4).unwrap();
        let desc = crate::device::NewDevice::new("sim-eng-extend", 36, 1500.0, 320.0, 9.5, true);
        let d = e.register_device(&desc).unwrap();
        assert!(
            !at.plan.extend_device(d),
            "the registration already appended this device's lane"
        );
        // The appended lane is bit-identical to a fresh dense build.
        let fresh = AnalyzedPlan::build(&at.trace, &e.predictor().metrics_policy);
        for precision in [Precision::Fp32, Precision::Amp] {
            let a = e.evaluate(&at.plan, d, precision);
            let b = e.evaluate(&fresh, d, precision);
            assert_eq!(a.run_time_ms().to_bits(), b.run_time_ms().to_bits());
        }
        // Idempotent re-registration neither errors nor re-extends.
        assert_eq!(e.register_device(&desc).unwrap(), d);
    }

    #[test]
    fn predict_cluster_world_one_is_bit_identical_to_predict() {
        let e = engine();
        let topos = [Topology::DGX, Topology::CLOUD];
        let worlds = [1usize, 2, 8, 64];
        let report = e
            .predict_cluster(
                "mlp",
                32,
                Device::T4,
                Device::V100,
                Precision::Fp32,
                &topos,
                &worlds,
                &ClusterParams::default(),
            )
            .unwrap();
        assert_eq!(report.configs.len(), topos.len() * worlds.len());
        let single = e.predict("mlp", 32, Device::T4, Device::V100, Precision::Fp32).unwrap();
        assert_eq!(report.compute_ms.to_bits(), single.pred.run_time_ms().to_bits());
        for cell in &report.configs {
            assert!(cell.pred.exposed_ms >= 0.0);
            assert!(cell.pred.efficiency > 0.0 && cell.pred.efficiency <= 1.0 + 1e-9);
            if cell.world == 1 {
                assert_eq!(
                    cell.pred.iter_ms.to_bits(),
                    single.pred.run_time_ms().to_bits(),
                    "{}: world=1 must reproduce the single-GPU path",
                    cell.topology
                );
            }
        }
    }

    #[test]
    fn rank_cluster_sorts_and_matches_the_scalar_composition() {
        let e = engine();
        let dests = [Device::V100, Device::T4];
        let topos = [Topology::DGX, Topology::CLOUD];
        let worlds = [1usize, 4, 16];
        let params = ClusterParams::default();
        let ranking = e
            .rank_cluster("mlp", 32, Device::T4, &dests, Precision::Fp32, &topos, &worlds, &params)
            .unwrap();
        assert_eq!(ranking.entries.len(), dests.len() * topos.len() * worlds.len());
        for pair in ranking.entries.windows(2) {
            assert_ne!(
                rank_order(
                    (pair[0].cost_normalized_throughput, pair[0].pred.throughput),
                    (pair[1].cost_normalized_throughput, pair[1].pred.throughput),
                ),
                std::cmp::Ordering::Greater,
                "entries must be in rank order"
            );
        }
        // Every entry is bit-identical to the per-destination report.
        for dest in dests {
            let report = e
                .predict_cluster("mlp", 32, Device::T4, dest, Precision::Fp32, &topos, &worlds, &params)
                .unwrap();
            for cell in &report.configs {
                let en = ranking
                    .entries
                    .iter()
                    .find(|en| {
                        en.dest == dest && en.topology == cell.topology && en.world == cell.world
                    })
                    .unwrap();
                assert_eq!(en.pred.iter_ms.to_bits(), cell.pred.iter_ms.to_bits());
                assert_eq!(en.pred.throughput.to_bits(), cell.pred.throughput.to_bits());
            }
        }
    }

    #[test]
    fn cluster_sweeps_reject_bad_grids() {
        let e = engine();
        let params = ClusterParams::default();
        assert!(e
            .predict_cluster("mlp", 32, Device::T4, Device::V100, Precision::Fp32, &[], &[1], &params)
            .is_err());
        assert!(e
            .predict_cluster(
                "mlp", 32, Device::T4, Device::V100, Precision::Fp32,
                &[Topology::DGX], &[], &params,
            )
            .is_err());
        assert!(e
            .predict_cluster(
                "mlp", 32, Device::T4, Device::V100, Precision::Fp32,
                &[Topology::DGX], &[0], &params,
            )
            .is_err());
        assert!(e
            .rank_cluster(
                "mlp", 32, Device::T4, &[], Precision::Fp32,
                &[Topology::DGX], &[1], &params,
            )
            .is_err());
    }

    #[test]
    fn exported_workload_is_consistent_with_the_cost_model() {
        let e = engine();
        let params = ClusterParams::default();
        let w = e
            .export_workload(
                "mlp", 32, Device::T4, Device::V100, Precision::Fp32,
                Topology::DGX, 16, &params,
            )
            .unwrap();
        assert_eq!(w.model, "mlp");
        assert_eq!(w.world, 16);
        assert_eq!(w.topology, "dgx");
        assert!(w.compute_ms > 0.0);
        assert!(!w.comm_ops.is_empty());
        for op in &w.comm_ops {
            assert!(op.bytes > 0.0);
            assert!(!op.participants.is_empty());
            assert!(op.participants.iter().all(|&r| r < 16));
        }
        // Round-trips through its JSON encoding.
        let parsed =
            comm::Workload::from_value(&crate::util::json::parse(&w.to_value().dump()).unwrap())
                .unwrap();
        assert_eq!(parsed, w);
    }

    #[test]
    fn clear_trace_cache_forces_retrack() {
        let e = engine();
        e.trace("mlp", 16, Device::T4).unwrap();
        e.clear_trace_cache();
        assert_eq!(e.stats().trace_entries, 0);
        e.trace("mlp", 16, Device::T4).unwrap();
        assert_eq!(e.stats().trace_misses, 2);
    }
}
