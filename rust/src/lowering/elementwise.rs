//! Kernel-alike lowering: elementwise ops, normalization, pooling,
//! softmax, embedding, losses, and the optimizer step.
//!
//! These ops use the *same* kernels on every GPU architecture (plain CUDA
//! kernels shipped with the framework, not cuDNN algorithm dispatch), so
//! their lowering ignores `arch` except through the hardware the simulator
//! later runs them on. This is precisely the population of operations wave
//! scaling is designed for.

use crate::device::{Arch, LaunchConfig};
use crate::lowering::{Kernel, Pass, Precision};
use crate::opgraph::shape::conv_out;
use crate::opgraph::{Op, OpKind, PoolKind};

/// Elements processed per thread in framework elementwise kernels.
const ELEMS_PER_THREAD: u64 = 4;
const EW_THREADS: u32 = 256;
const EW_REGS: u32 = 24;

/// Build a generic streaming kernel over `n` elements.
///
/// * `flops_per_elem` — arithmetic per element,
/// * `streams` — tensor streams touched per element (reads + writes).
pub fn ew_kernel(
    name: &str,
    n: usize,
    flops_per_elem: f64,
    streams: f64,
    precision: Precision,
) -> Kernel {
    let grid = (n as u64).div_ceil(EW_THREADS as u64 * ELEMS_PER_THREAD).max(1);
    Kernel {
        name: name.to_string(),
        launch: LaunchConfig::new(grid, EW_THREADS, EW_REGS, 0),
        flops: n as f64 * flops_per_elem,
        dram_bytes: n as f64 * streams * precision.elem_bytes(),
        tensor_core_eligible: false,
    }
}

/// A reduction-style kernel (normalization statistics, loss reduction):
/// same streaming traffic but a two-stage launch with some shared memory.
pub fn reduce_kernel(name: &str, n: usize, flops_per_elem: f64, streams: f64, precision: Precision) -> Kernel {
    let grid = (n as u64).div_ceil(EW_THREADS as u64 * ELEMS_PER_THREAD * 4).max(1);
    Kernel {
        name: name.to_string(),
        launch: LaunchConfig::new(grid, EW_THREADS, 32, 4 * 1024),
        flops: n as f64 * flops_per_elem,
        dram_bytes: n as f64 * streams * precision.elem_bytes(),
        tensor_core_eligible: false,
    }
}

/// Lower every kernel-alike op kind.
pub fn lower_simple(op: &Op, _arch: Arch, precision: Precision, pass: Pass) -> Vec<Kernel> {
    let n = op.input_numel();
    match &op.kind {
        OpKind::Elementwise { kind } => {
            let base = op.kind.short_name();
            match pass {
                Pass::Forward => vec![ew_kernel(
                    base,
                    n,
                    kind.flops_per_elem(),
                    kind.mem_streams(),
                    precision,
                )],
                // Activations/arithmetic have an elementwise backward of
                // similar cost (grad_out → grad_in, possibly with a mask).
                Pass::Backward => vec![ew_kernel(
                    &format!("{base}_bwd"),
                    n,
                    kind.flops_per_elem(),
                    kind.mem_streams(),
                    precision,
                )],
            }
        }
        OpKind::BatchNorm2d { .. } => match pass {
            Pass::Forward => vec![
                reduce_kernel("bn_stats", n, 3.0, 1.0, precision),
                ew_kernel("bn_apply", n, 4.0, 2.0, precision),
            ],
            Pass::Backward => vec![
                reduce_kernel("bn_bwd_stats", n, 4.0, 2.0, precision),
                ew_kernel("bn_bwd_apply", n, 5.0, 3.0, precision),
            ],
        },
        OpKind::LayerNorm { .. } => match pass {
            Pass::Forward => vec![
                reduce_kernel("ln_stats", n, 3.0, 1.0, precision),
                ew_kernel("ln_apply", n, 4.0, 2.0, precision),
            ],
            Pass::Backward => vec![
                reduce_kernel("ln_bwd_stats", n, 4.0, 2.0, precision),
                ew_kernel("ln_bwd_apply", n, 5.0, 3.0, precision),
            ],
        },
        OpKind::Pool2d {
            kind,
            kernel,
            stride,
            padding,
        } => {
            // Output elements: batch × ch × h' × w'.
            let (b, c, h, w) = (op.input[0], op.input[1], op.input[2], op.input[3]);
            let (oh, ow) = match kind {
                PoolKind::AdaptiveAvg => (1, 1),
                _ => (
                    conv_out(h, *kernel, *stride, *padding),
                    conv_out(w, *kernel, *stride, *padding),
                ),
            };
            let out_n = b * c * oh * ow;
            let window = match kind {
                PoolKind::AdaptiveAvg => (h * w) as f64,
                _ => (*kernel * *kernel) as f64,
            };
            let name = op.kind.short_name();
            match pass {
                Pass::Forward => {
                    // Reads the full input once, writes the output.
                    let mut k = ew_kernel(name, out_n, window, 1.0, precision);
                    k.dram_bytes += n as f64 * precision.elem_bytes();
                    vec![k]
                }
                Pass::Backward => {
                    let mut k = ew_kernel(&format!("{name}_bwd"), out_n, window, 1.0, precision);
                    k.dram_bytes += n as f64 * precision.elem_bytes();
                    vec![k]
                }
            }
        }
        OpKind::Softmax { .. } => match pass {
            Pass::Forward => vec![reduce_kernel("softmax", n, 8.0, 3.0, precision)],
            Pass::Backward => vec![reduce_kernel("softmax_bwd", n, 6.0, 3.0, precision)],
        },
        OpKind::Embedding { dim, .. } => {
            let rows: usize = op.input.iter().product();
            let moved = rows * dim;
            match pass {
                // Gather: index read + row copy.
                Pass::Forward => vec![ew_kernel("embedding", moved, 0.0, 2.0, precision)],
                // Scatter-add into the weight gradient; atomics make it
                // notably heavier than the gather.
                Pass::Backward => vec![ew_kernel("scatter", moved, 1.0, 3.0, precision)],
            }
        }
        OpKind::CrossEntropy { .. } => match pass {
            Pass::Forward => vec![reduce_kernel("cross_entropy", n, 10.0, 2.0, precision)],
            Pass::Backward => vec![ew_kernel("cross_entropy_bwd", n, 4.0, 3.0, precision)],
        },
        OpKind::Concat { inputs } => match pass {
            // A concat is `inputs` contiguous copies.
            Pass::Forward => vec![ew_kernel("cat", n, 0.0, 2.0, precision)],
            Pass::Backward => vec![ew_kernel("cat_bwd", n, 0.0, 2.0, precision)]
                .into_iter()
                .chain(std::iter::once(ew_kernel(
                    "cat_grad_split",
                    n / inputs.max(&1),
                    0.0,
                    2.0,
                    precision,
                )))
                .collect(),
        },
        // The optimizer runs once per iteration, after backward. It is
        // attached to the backward pass; optimizer state stays FP32 even
        // under AMP.
        OpKind::OptimizerStep { kind, params } => match pass {
            Pass::Forward => vec![],
            Pass::Backward => {
                let p = *params as usize;
                let (name, flops, streams) = match kind {
                    crate::opgraph::OptimizerKind::Sgd => ("sgd_step", 4.0, 4.0),
                    crate::opgraph::OptimizerKind::Adam => ("adam_step", 12.0, 6.0),
                };
                vec![ew_kernel(name, p, flops, streams, Precision::Fp32)]
            }
        },
        _ => unreachable!("lower_simple called on kernel-varying op"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::opgraph::{EwKind, OptimizerKind};

    #[test]
    fn ew_kernel_grid_and_traffic() {
        let k = ew_kernel("relu", 1 << 20, 1.0, 2.0, Precision::Fp32);
        assert_eq!(k.launch.grid_blocks, (1 << 20) / (256 * 4));
        assert_eq!(k.dram_bytes, (1 << 20) as f64 * 2.0 * 4.0);
        assert!(!k.tensor_core_eligible);
    }

    #[test]
    fn elementwise_is_memory_bound() {
        let k = ew_kernel("add", 1 << 20, 2.0, 3.0, Precision::Fp32);
        // Arithmetic intensity ≪ 1 FLOP/byte — firmly memory-bound.
        assert!(k.arith_intensity() < 1.0);
    }

    #[test]
    fn amp_halves_elementwise_traffic() {
        let a = ew_kernel("relu", 1000, 1.0, 2.0, Precision::Fp32);
        let b = ew_kernel("relu", 1000, 1.0, 2.0, Precision::Amp);
        assert!((a.dram_bytes / b.dram_bytes - 2.0).abs() < 1e-9);
    }

    #[test]
    fn batchnorm_has_two_kernels_each_pass() {
        let op = Op::new(
            "bn",
            OpKind::BatchNorm2d { channels: 64 },
            vec![32, 64, 56, 56],
        );
        assert_eq!(lower_simple(&op, Arch::Volta, Precision::Fp32, Pass::Forward).len(), 2);
        assert_eq!(lower_simple(&op, Arch::Volta, Precision::Fp32, Pass::Backward).len(), 2);
    }

    #[test]
    fn maxpool_output_sized() {
        let op = Op::new(
            "pool",
            OpKind::Pool2d {
                kind: PoolKind::Max,
                kernel: 3,
                stride: 2,
                padding: 1,
            },
            vec![32, 64, 112, 112],
        );
        let k = &lower_simple(&op, Arch::Volta, Precision::Fp32, Pass::Forward)[0];
        // 112 → 56; flops = out_elems × 9 window compares.
        assert_eq!(k.flops, (32 * 64 * 56 * 56) as f64 * 9.0);
    }

    #[test]
    fn optimizer_only_in_backward() {
        let op = Op::new(
            "opt",
            OpKind::OptimizerStep {
                kind: OptimizerKind::Adam,
                params: 1_000_000,
            },
            vec![1],
        );
        assert!(lower_simple(&op, Arch::Volta, Precision::Fp32, Pass::Forward).is_empty());
        let bwd = lower_simple(&op, Arch::Volta, Precision::Fp32, Pass::Backward);
        assert_eq!(bwd.len(), 1);
        assert_eq!(bwd[0].name, "adam_step");
        assert_eq!(bwd[0].flops, 12.0 * 1e6);
    }

    #[test]
    fn embedding_backward_is_scatter() {
        let op = Op::new(
            "emb",
            OpKind::Embedding {
                vocab: 32000,
                dim: 512,
            },
            vec![64, 50],
        );
        let bwd = lower_simple(&op, Arch::Volta, Precision::Fp32, Pass::Backward);
        assert_eq!(bwd[0].name, "scatter");
        let fwd = lower_simple(&op, Arch::Volta, Precision::Fp32, Pass::Forward);
        assert!(bwd[0].dram_bytes > fwd[0].dram_bytes);
    }

    #[test]
    fn relu_backward_mirrors_forward_cost() {
        let op = Op::new("r", OpKind::Elementwise { kind: EwKind::Relu }, vec![4096]);
        let f = &lower_simple(&op, Arch::Pascal, Precision::Fp32, Pass::Forward)[0];
        let b = &lower_simple(&op, Arch::Pascal, Precision::Fp32, Pass::Backward)[0];
        assert_eq!(f.dram_bytes, b.dram_bytes);
        assert_eq!(b.name, "relu_bwd");
    }
}
