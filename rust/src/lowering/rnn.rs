//! LSTM lowering with cuDNN-style per-architecture algorithm selection.
//!
//! cuDNN implements recurrent layers two ways:
//!
//! * **Standard** (Pascal-era default): the input projection of all
//!   timesteps is one large batched GEMM; the recurrent projection is a
//!   per-timestep GEMM chain plus pointwise gate kernels. Many kernel
//!   launches, weights re-read every timestep.
//! * **Persistent** (Volta/Turing, small-enough hidden state): recurrent
//!   weights stay resident in register files/smem across timesteps; one
//!   long-running kernel per layer. Far fewer launches and much less
//!   weight traffic — a different kernel entirely.
//!
//! The selection is architecture- and shape-dependent, making LSTM the
//! second canonical *kernel-varying* op (§3.2).

use crate::device::{Arch, LaunchConfig};
use crate::lowering::gemm::{arch_l2_kib, gemm_kernel};
use crate::lowering::{elementwise::ew_kernel, Kernel, Pass, Precision};
use crate::opgraph::{Op, OpKind};

/// RNN algorithm chosen for a layer.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RnnAlgo {
    Standard,
    Persistent,
}

/// cuDNN-style selection: persistent kernels need tensor-core-era SMs and
/// a recurrent matrix small enough to stay resident.
pub fn select_rnn_algo(arch: Arch, hidden: usize, batch: usize) -> RnnAlgo {
    match arch {
        Arch::Pascal => RnnAlgo::Standard,
        Arch::Volta | Arch::Turing => {
            if hidden <= 1024 && batch <= 96 {
                RnnAlgo::Persistent
            } else {
                RnnAlgo::Standard
            }
        }
    }
}

/// The persistent-RNN kernel for one direction of one layer over the
/// full sequence.
fn persistent_kernel(
    tag: &str,
    arch: Arch,
    batch: usize,
    in_dim: usize,
    hidden: usize,
    seq: usize,
    precision: Precision,
) -> Kernel {
    let eb = precision.elem_bytes();
    // Gate math for the whole sequence: input + recurrent projections.
    let flops = 2.0 * (seq * batch) as f64 * (4 * hidden) as f64 * (in_dim + hidden) as f64
        + (seq * batch * hidden) as f64 * 30.0; // pointwise gate ops fused in
    // Weights are loaded once (that is the point of persistence);
    // activations stream per timestep.
    let weight_bytes = ((4 * hidden) * (in_dim + hidden)) as f64 * eb;
    let act_bytes = (seq * batch) as f64 * (in_dim + 2 * hidden) as f64 * eb * 2.0;
    // Grid sized to fill the chip once — persistent blocks never rotate.
    let grid = match arch {
        Arch::Volta => 160,
        Arch::Turing => 80,
        Arch::Pascal => 56,
    };
    Kernel {
        name: format!("persist_lstm_{tag}"),
        launch: LaunchConfig::new(grid, 256, 200, 32 * 1024),
        flops,
        dram_bytes: weight_bytes + act_bytes,
        tensor_core_eligible: true,
    }
}

/// Standard-algorithm kernels for one direction of one layer.
fn standard_kernels(
    tag: &str,
    arch: Arch,
    batch: usize,
    in_dim: usize,
    hidden: usize,
    seq: usize,
    precision: Precision,
) -> Vec<Kernel> {
    let l2 = arch_l2_kib(arch);
    let mut kernels = Vec::new();
    // One big GEMM for all timesteps' input projection: [seq·b] × [4h × in].
    kernels.push(gemm_kernel(
        &format!("lstm_{tag}_xproj"),
        1,
        seq * batch,
        4 * hidden,
        in_dim,
        arch,
        precision,
        l2,
    ));
    // Recurrent chain: represented as one kernel descriptor whose cost is
    // the whole per-timestep GEMM sequence (grid = per-step grid; the
    // simulator's tail-wave model sees each step's small launch through
    // seq × launch overhead, which we fold in via the step count).
    let mut rec = gemm_kernel(
        &format!("lstm_{tag}_hproj_steps"),
        seq, // one GEMM per timestep
        batch,
        4 * hidden,
        hidden,
        arch,
        precision,
        l2,
    );
    // Weights are re-read every timestep in the standard algorithm; the
    // batched estimate already multiplies traffic by `seq`.
    rec.name = format!("lstm_{tag}_hproj_x{seq}");
    kernels.push(rec);
    // Pointwise gate kernel per timestep, folded into one descriptor.
    kernels.push(ew_kernel(
        &format!("lstm_{tag}_cell"),
        seq * batch * hidden,
        30.0,
        6.0,
        precision,
    ));
    kernels
}

/// Lower an `Lstm` op for one pass.
pub fn lower_lstm(op: &Op, arch: Arch, precision: Precision, pass: Pass) -> Vec<Kernel> {
    let OpKind::Lstm {
        input,
        hidden,
        layers,
        seq,
        bidirectional,
        ..
    } = op.kind
    else {
        unreachable!("lower_lstm called on non-LSTM op")
    };
    let batch = op.input[1]; // [seq, batch, features]
    let dirs = if bidirectional { 2 } else { 1 };
    let algo = select_rnn_algo(arch, hidden, batch);

    let mut kernels = Vec::new();
    for layer in 0..layers {
        let in_dim = if layer == 0 { input } else { hidden * dirs };
        for dir in 0..dirs {
            let tag = format!("l{layer}d{dir}");
            let mut layer_kernels = match algo {
                RnnAlgo::Persistent => {
                    vec![persistent_kernel(&tag, arch, batch, in_dim, hidden, seq, precision)]
                }
                RnnAlgo::Standard => {
                    standard_kernels(&tag, arch, batch, in_dim, hidden, seq, precision)
                }
            };
            if pass == Pass::Backward {
                // Backward re-runs the recurrence (dgrad) and adds wgrad
                // accumulation: ≈2× forward cost, expressed by doubling
                // flops/bytes and renaming.
                for k in &mut layer_kernels {
                    k.flops *= 2.0;
                    k.dram_bytes *= 2.0;
                    k.name = format!("{}_bwd", k.name);
                }
            }
            kernels.append(&mut layer_kernels);
        }
    }
    kernels
}

#[cfg(test)]
mod tests {
    use super::*;

    fn lstm_op(input: usize, hidden: usize, layers: usize, seq: usize, batch: usize) -> Op {
        Op::new(
            "lstm",
            OpKind::Lstm {
                input,
                hidden,
                layers,
                seq,
                bidirectional: false,
                bias: true,
            },
            vec![seq, batch, input],
        )
    }

    #[test]
    fn algo_selection_matches_cudnn_shape_rules() {
        assert_eq!(select_rnn_algo(Arch::Pascal, 512, 32), RnnAlgo::Standard);
        assert_eq!(select_rnn_algo(Arch::Volta, 512, 32), RnnAlgo::Persistent);
        assert_eq!(select_rnn_algo(Arch::Volta, 2048, 32), RnnAlgo::Standard);
        assert_eq!(select_rnn_algo(Arch::Turing, 512, 128), RnnAlgo::Standard);
    }

    #[test]
    fn persistent_moves_less_dram_than_standard() {
        let op = lstm_op(512, 512, 1, 50, 32);
        let volta: f64 = lower_lstm(&op, Arch::Volta, Precision::Fp32, Pass::Forward)
            .iter()
            .map(|k| k.dram_bytes)
            .sum();
        let pascal: f64 = lower_lstm(&op, Arch::Pascal, Precision::Fp32, Pass::Forward)
            .iter()
            .map(|k| k.dram_bytes)
            .sum();
        assert!(volta < pascal, "persistent algo must save weight traffic");
    }

    #[test]
    fn kernel_names_differ_across_archs() {
        let op = lstm_op(256, 256, 1, 20, 16);
        let v = lower_lstm(&op, Arch::Volta, Precision::Fp32, Pass::Forward);
        let p = lower_lstm(&op, Arch::Pascal, Precision::Fp32, Pass::Forward);
        assert!(v[0].name.starts_with("persist_lstm"));
        assert!(p[0].name.contains("xproj"));
    }

    #[test]
    fn layers_and_directions_multiply_kernels() {
        let op = lstm_op(256, 256, 1, 20, 16);
        let one = lower_lstm(&op, Arch::Volta, Precision::Fp32, Pass::Forward).len();
        let op2 = Op::new(
            "lstm",
            OpKind::Lstm {
                input: 256,
                hidden: 256,
                layers: 2,
                seq: 20,
                bidirectional: true,
                bias: true,
            },
            vec![20, 16, 256],
        );
        let four = lower_lstm(&op2, Arch::Volta, Precision::Fp32, Pass::Forward).len();
        assert_eq!(four, one * 4);
    }

    #[test]
    fn backward_doubles_cost() {
        let op = lstm_op(512, 1024, 2, 50, 64);
        let f: f64 = lower_lstm(&op, Arch::Pascal, Precision::Fp32, Pass::Forward)
            .iter()
            .map(|k| k.flops)
            .sum();
        let b: f64 = lower_lstm(&op, Arch::Pascal, Precision::Fp32, Pass::Backward)
            .iter()
            .map(|k| k.flops)
            .sum();
        assert!((b / f - 2.0).abs() < 1e-9);
    }

    #[test]
    fn stacked_layer_input_dim_follows_hidden() {
        // With hidden ≠ input the layer-1 projection must use hidden dims.
        let op = lstm_op(128, 512, 2, 10, 8);
        let kernels = lower_lstm(&op, Arch::Pascal, Precision::Fp32, Pass::Forward);
        // layer0 xproj k-dim = 128; layer1 xproj k-dim = 512.
        // FLOPs layer1 xproj > layer0 xproj.
        let l0 = kernels.iter().find(|k| k.name.contains("l0d0_xproj")).unwrap();
        let l1 = kernels.iter().find(|k| k.name.contains("l1d0_xproj")).unwrap();
        assert!(l1.flops > l0.flops);
    }
}
