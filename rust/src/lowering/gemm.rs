//! Dense matrix-multiply lowering (cuBLAS stand-in).
//!
//! Everything GEMM-shaped funnels through [`gemm_kernel`]: linear layers,
//! batched matmuls, the im2col form of convolutions, and LSTM gate
//! projections. Tile shapes, register budgets, and shared-memory staging
//! differ per GPU generation — exactly the arch-specific dispatch cuBLAS
//! does — which is what makes these operations *kernel-varying*.

use crate::device::{Arch, LaunchConfig};
use crate::lowering::{Kernel, Pass, Precision};
use crate::opgraph::{Op, OpKind};

/// Tile configuration chosen for a GEMM on a given architecture.
#[derive(Debug, Clone, Copy)]
pub struct GemmTile {
    pub tile_m: usize,
    pub tile_n: usize,
    pub threads: u32,
    pub regs: u32,
    pub smem: u32,
    pub tag: &'static str,
}

/// Architecture-specific tile selection — the cuBLAS heuristic stand-in.
/// Larger tiles amortize memory traffic but need more registers/smem;
/// newer architectures afford bigger tiles.
pub fn select_tile(arch: Arch, m: usize, n: usize) -> GemmTile {
    let small = m < 128 || n < 128;
    match (arch, small) {
        (Arch::Pascal, false) => GemmTile {
            tile_m: 128,
            tile_n: 64,
            threads: 256,
            regs: 120,
            smem: 16 * 1024,
            tag: "pascal_sgemm_128x64",
        },
        (Arch::Pascal, true) => GemmTile {
            tile_m: 64,
            tile_n: 64,
            threads: 128,
            regs: 96,
            smem: 8 * 1024,
            tag: "pascal_sgemm_64x64",
        },
        (Arch::Volta, false) => GemmTile {
            tile_m: 128,
            tile_n: 128,
            threads: 256,
            regs: 128,
            smem: 32 * 1024,
            tag: "volta_sgemm_128x128",
        },
        (Arch::Volta, true) => GemmTile {
            tile_m: 64,
            tile_n: 64,
            threads: 128,
            regs: 90,
            smem: 16 * 1024,
            tag: "volta_sgemm_64x64",
        },
        (Arch::Turing, false) => GemmTile {
            tile_m: 128,
            tile_n: 128,
            threads: 256,
            regs: 144,
            smem: 48 * 1024,
            tag: "turing_sgemm_128x128",
        },
        (Arch::Turing, true) => GemmTile {
            tile_m: 64,
            tile_n: 64,
            threads: 128,
            regs: 112,
            smem: 24 * 1024,
            tag: "turing_sgemm_64x64",
        },
    }
}

/// L2-aware DRAM traffic estimate for a tiled GEMM.
///
/// With an `tm × tn` output tiling, the A operand is streamed once per
/// column of tiles and B once per row of tiles — unless the operand fits
/// in (half of) L2, in which case re-reads are served on chip. The L2
/// size is per-architecture, so the same GEMM moves different DRAM bytes
/// on different GPUs (one of the effects wave scaling cannot see and the
/// simulator deliberately includes).
pub fn gemm_traffic(
    batches: usize,
    m: usize,
    n: usize,
    k: usize,
    tile: &GemmTile,
    l2_bytes: f64,
    elem_bytes: f64,
) -> f64 {
    let tiles_m = m.div_ceil(tile.tile_m) as f64;
    let tiles_n = n.div_ceil(tile.tile_n) as f64;
    let a_bytes = (m * k) as f64 * elem_bytes;
    let b_bytes = (k * n) as f64 * elem_bytes;
    let c_bytes = (m * n) as f64 * elem_bytes;
    // Re-read factor: capped by tile count; 1.0 when the operand is L2-hot.
    let a_rereads = if a_bytes <= 0.5 * l2_bytes { 1.0 } else { tiles_n.min(4.0) };
    let b_rereads = if b_bytes <= 0.5 * l2_bytes { 1.0 } else { tiles_m.min(4.0) };
    batches as f64 * (a_bytes * a_rereads + b_bytes * b_rereads + c_bytes)
}

/// Build the kernel descriptor for one (possibly batched) GEMM:
/// `C[b] = A[b]·B[b]`, `A: m×k`, `B: k×n`.
pub fn gemm_kernel(
    name_hint: &str,
    batches: usize,
    m: usize,
    n: usize,
    k: usize,
    arch: Arch,
    precision: Precision,
    l2_kib: u32,
) -> Kernel {
    let tile = select_tile(arch, m, n);
    let grid = (batches * m.div_ceil(tile.tile_m) * n.div_ceil(tile.tile_n)) as u64;
    let elem_bytes = precision.elem_bytes();
    let flops = 2.0 * batches as f64 * m as f64 * n as f64 * k as f64;
    let dram_bytes = gemm_traffic(batches, m, n, k, &tile, l2_kib as f64 * 1024.0, elem_bytes);
    Kernel {
        name: format!("{}_{}", tile.tag, name_hint),
        launch: LaunchConfig::new(grid.max(1), tile.threads, tile.regs, tile.smem),
        flops,
        dram_bytes,
        tensor_core_eligible: true,
    }
}

/// Default L2 size used when the lowering is asked for an architecture
/// without a concrete device (arch representative: the server part).
pub fn arch_l2_kib(arch: Arch) -> u32 {
    match arch {
        Arch::Pascal => 4096,
        Arch::Volta => 6144,
        Arch::Turing => 4096,
    }
}

/// Lower `Linear` and `BatchedMatmul` ops.
pub fn lower_dense(op: &Op, arch: Arch, precision: Precision, pass: Pass) -> Vec<Kernel> {
    let l2 = arch_l2_kib(arch);
    match op.kind {
        OpKind::Linear {
            in_features,
            out_features,
            bias,
        } => {
            let rows: usize = op.input[..op.input.len() - 1].iter().product();
            let mut kernels = Vec::new();
            match pass {
                Pass::Forward => {
                    // y = x·Wᵀ (+ b)
                    kernels.push(gemm_kernel(
                        "linear_fwd",
                        1,
                        rows.max(1),
                        out_features,
                        in_features,
                        arch,
                        precision,
                        l2,
                    ));
                    if bias {
                        kernels.push(crate::lowering::elementwise::ew_kernel(
                            "bias_add",
                            rows * out_features,
                            1.0,
                            2.0,
                            precision,
                        ));
                    }
                }
                Pass::Backward => {
                    // dX = dY·W  and  dW = dYᵀ·X
                    kernels.push(gemm_kernel(
                        "linear_dgrad",
                        1,
                        rows.max(1),
                        in_features,
                        out_features,
                        arch,
                        precision,
                        l2,
                    ));
                    kernels.push(gemm_kernel(
                        "linear_wgrad",
                        1,
                        out_features,
                        in_features,
                        rows.max(1),
                        arch,
                        precision,
                        l2,
                    ));
                    if bias {
                        kernels.push(crate::lowering::elementwise::ew_kernel(
                            "bias_grad",
                            rows * out_features,
                            1.0,
                            1.0,
                            precision,
                        ));
                    }
                }
            }
            kernels
        }
        OpKind::BatchedMatmul { b, l, m, r } => match pass {
            Pass::Forward => vec![gemm_kernel("bmm_fwd", b, l, r, m, arch, precision, l2)],
            Pass::Backward => vec![
                // dA = dC·Bᵀ, dB = Aᵀ·dC
                gemm_kernel("bmm_dgrad_a", b, l, m, r, arch, precision, l2),
                gemm_kernel("bmm_dgrad_b", b, m, r, l, arch, precision, l2),
            ],
        },
        _ => unreachable!("lower_dense called on non-dense op"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flops_formula() {
        let k = gemm_kernel("t", 1, 64, 128, 256, Arch::Volta, Precision::Fp32, 6144);
        assert_eq!(k.flops, 2.0 * 64.0 * 128.0 * 256.0);
        assert!(k.tensor_core_eligible);
    }

    #[test]
    fn grid_covers_output_tiles() {
        let k = gemm_kernel("t", 2, 300, 300, 64, Arch::Volta, Precision::Fp32, 6144);
        // 300/128 → 3 tiles each way, ×2 batches.
        assert_eq!(k.launch.grid_blocks, 2 * 3 * 3);
    }

    #[test]
    fn tile_selection_is_arch_specific() {
        let p = select_tile(Arch::Pascal, 1024, 1024);
        let v = select_tile(Arch::Volta, 1024, 1024);
        let t = select_tile(Arch::Turing, 1024, 1024);
        assert_ne!(p.tag, v.tag);
        assert_ne!(v.tag, t.tag);
        assert_eq!(p.tile_n, 64);
        assert_eq!(v.tile_n, 128);
    }

    #[test]
    fn small_gemm_uses_small_tile() {
        let t = select_tile(Arch::Volta, 64, 2048);
        assert_eq!(t.tile_m, 64);
    }

    #[test]
    fn l2_hot_operand_reduces_traffic() {
        let tile = select_tile(Arch::Volta, 4096, 4096);
        let cold = gemm_traffic(1, 4096, 4096, 4096, &tile, 1.0, 4.0);
        let hot = gemm_traffic(1, 4096, 4096, 4096, &tile, 1e12, 4.0);
        assert!(cold > hot);
    }

    #[test]
    fn linear_backward_has_two_gemms() {
        let op = Op::new(
            "fc",
            OpKind::Linear {
                in_features: 512,
                out_features: 256,
                bias: true,
            },
            vec![64, 512],
        );
        let bwd = lower_dense(&op, Arch::Turing, Precision::Fp32, Pass::Backward);
        assert_eq!(bwd.len(), 3); // dgrad + wgrad + bias_grad
        let fwd = lower_dense(&op, Arch::Turing, Precision::Fp32, Pass::Forward);
        let fwd_flops: f64 = fwd.iter().map(|k| k.flops).sum();
        let bwd_flops: f64 = bwd.iter().map(|k| k.flops).sum();
        // Backward ≈ 2× forward FLOPs for dense layers.
        assert!(bwd_flops > 1.8 * fwd_flops && bwd_flops < 2.2 * fwd_flops);
    }

    #[test]
    fn bmm_dims_from_kind() {
        let op = Op::new(
            "attn_scores",
            OpKind::BatchedMatmul {
                b: 8 * 16,
                l: 50,
                m: 64,
                r: 50,
            },
            vec![8 * 16, 50, 64],
        );
        let fwd = lower_dense(&op, Arch::Volta, Precision::Fp32, Pass::Forward);
        assert_eq!(fwd.len(), 1);
        assert_eq!(fwd[0].flops, 2.0 * 128.0 * 50.0 * 50.0 * 64.0);
    }
}
