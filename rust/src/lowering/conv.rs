//! Convolution lowering with cuDNN-style algorithm selection.
//!
//! The paper singles out convolution as the canonical *kernel-varying*
//! operation: cuDNN picks different algorithms (and therefore entirely
//! different kernels) on different GPU generations [44, 75]. We reproduce
//! that with a deterministic selection heuristic:
//!
//! * 1×1 convolutions are exact GEMMs on every architecture.
//! * 3×3 stride-1 convolutions with enough channels use **Winograd**
//!   F(2×2, 3×3) on Volta/Turing (2.25× FLOP reduction, extra transform
//!   traffic), but **implicit GEMM** on Pascal — so the *same op* has
//!   different FLOP counts on different GPUs, which a pure scaling rule
//!   cannot capture. This is what the conv2d MLP learns.
//! * Everything else lowers to implicit GEMM (im2col-free tiled GEMM).
//!
//! Backward lowers to a data-gradient and a weight-gradient kernel, like
//! cuDNN's `dgrad`/`wgrad`.

use crate::device::{Arch, LaunchConfig};
use crate::lowering::gemm::{arch_l2_kib, gemm_kernel};
use crate::lowering::{elementwise::ew_kernel, Kernel, Pass, Precision};
use crate::opgraph::shape::{conv_out, conv_transpose_out};
use crate::opgraph::{Op, OpKind};

/// Convolution algorithm chosen by the cuDNN stand-in.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ConvAlgo {
    ImplicitGemm,
    Winograd,
}

/// Deterministic algorithm-selection heuristic (per arch + shape), the
/// stand-in for `cudnnFindConvolutionForwardAlgorithm`.
pub fn select_algo(arch: Arch, in_ch: usize, out_ch: usize, kernel: usize, stride: usize) -> ConvAlgo {
    let winograd_capable = kernel == 3 && stride == 1 && in_ch >= 32 && out_ch >= 32;
    match arch {
        // Pascal-era cuDNN rarely won with Winograd on these parts.
        Arch::Pascal => ConvAlgo::ImplicitGemm,
        Arch::Volta | Arch::Turing => {
            if winograd_capable {
                ConvAlgo::Winograd
            } else {
                ConvAlgo::ImplicitGemm
            }
        }
    }
}

/// Winograd F(2×2, 3×3) kernel descriptor.
fn winograd_kernel(
    tag: &str,
    batch: usize,
    in_ch: usize,
    out_ch: usize,
    oh: usize,
    ow: usize,
    precision: Precision,
) -> Kernel {
    // Direct conv FLOPs reduced 2.25×; transforms add ~15% back.
    let direct_flops = 2.0 * (batch * oh * ow * out_ch * in_ch * 9) as f64;
    let flops = direct_flops / 2.25 * 1.15;
    let eb = precision.elem_bytes();
    // Input/output tiles plus transformed-weight traffic; Winograd's
    // transformed domain inflates activation traffic by (4/2)² / reuse ≈ 2.3.
    let dram_bytes = ((batch * in_ch * oh * ow) as f64 * 2.3
        + (batch * out_ch * oh * ow) as f64
        + (in_ch * out_ch * 16) as f64)
        * eb;
    // One block per 8×8-output supertile per 32 output channels.
    let tiles = (batch * oh.div_ceil(8) * ow.div_ceil(8) * out_ch.div_ceil(32)) as u64;
    Kernel {
        name: format!("winograd_{tag}_3x3"),
        launch: LaunchConfig::new(tiles.max(1), 256, 168, 48 * 1024),
        flops,
        dram_bytes,
        tensor_core_eligible: true,
    }
}

/// Implicit-GEMM convolution kernel: GEMM of `out_ch × (N·H'·W')` by
/// reduction dim `in_ch·k²`, with im2col-style input re-reads.
fn implicit_gemm_kernel(
    tag: &str,
    arch: Arch,
    batch: usize,
    in_ch: usize,
    out_ch: usize,
    kernel: usize,
    oh: usize,
    ow: usize,
    precision: Precision,
) -> Kernel {
    let m = out_ch;
    let n = batch * oh * ow;
    let k = in_ch * kernel * kernel;
    let mut g = gemm_kernel(tag, 1, m, n, k, arch, precision, arch_l2_kib(arch));
    g.name = format!("implicit_gemm_{}", g.name);
    // im2col re-touches each input element ~k²/stride² times; the tiled
    // formulation keeps most of that in smem/L2 — model a 1.6× activation
    // traffic inflation over the plain GEMM estimate.
    g.dram_bytes *= 1.6;
    g
}

/// Lower `Conv2d` / `ConvTranspose2d` for one pass.
pub fn lower_conv(op: &Op, arch: Arch, precision: Precision, pass: Pass) -> Vec<Kernel> {
    match op.kind {
        OpKind::Conv2d {
            in_ch,
            out_ch,
            kernel,
            stride,
            padding,
            bias,
        } => {
            let (batch, h, w) = (op.input[0], op.input[2], op.input[3]);
            let (oh, ow) = (
                conv_out(h, kernel, stride, padding),
                conv_out(w, kernel, stride, padding),
            );
            let algo = select_algo(arch, in_ch, out_ch, kernel, stride);
            let mut kernels = Vec::new();
            match (pass, algo) {
                (Pass::Forward, ConvAlgo::Winograd) => {
                    kernels.push(winograd_kernel("fwd", batch, in_ch, out_ch, oh, ow, precision));
                }
                (Pass::Forward, ConvAlgo::ImplicitGemm) => {
                    kernels.push(implicit_gemm_kernel(
                        "conv_fwd", arch, batch, in_ch, out_ch, kernel, oh, ow, precision,
                    ));
                }
                (Pass::Backward, ConvAlgo::Winograd) => {
                    kernels.push(winograd_kernel("dgrad", batch, out_ch, in_ch, h, w, precision));
                    // wgrad has no efficient Winograd form — cuDNN falls
                    // back to implicit GEMM for it.
                    kernels.push(implicit_gemm_kernel(
                        "conv_wgrad", arch, batch, in_ch, out_ch, kernel, oh, ow, precision,
                    ));
                }
                (Pass::Backward, ConvAlgo::ImplicitGemm) => {
                    kernels.push(implicit_gemm_kernel(
                        "conv_dgrad", arch, batch, out_ch, in_ch, kernel, h, w, precision,
                    ));
                    kernels.push(implicit_gemm_kernel(
                        "conv_wgrad", arch, batch, in_ch, out_ch, kernel, oh, ow, precision,
                    ));
                }
            }
            if bias {
                let n_out = batch * out_ch * oh * ow;
                kernels.push(match pass {
                    Pass::Forward => ew_kernel("conv_bias", n_out, 1.0, 2.0, precision),
                    Pass::Backward => ew_kernel("conv_bias_grad", n_out, 1.0, 1.0, precision),
                });
            }
            kernels
        }
        OpKind::ConvTranspose2d {
            in_ch,
            out_ch,
            kernel,
            stride,
            padding,
            bias,
        } => {
            // A transposed conv is the data-gradient of a conv with swapped
            // channel roles: lower it as implicit GEMM over the *output*
            // spatial extent. Kernel-varying (uses the conv2d MLP).
            let (batch, h, w) = (op.input[0], op.input[2], op.input[3]);
            let (oh, ow) = (
                conv_transpose_out(h, kernel, stride, padding),
                conv_transpose_out(w, kernel, stride, padding),
            );
            let mut kernels = Vec::new();
            match pass {
                Pass::Forward => kernels.push(implicit_gemm_kernel(
                    "convT_fwd", arch, batch, in_ch, out_ch, kernel, oh, ow, precision,
                )),
                Pass::Backward => {
                    kernels.push(implicit_gemm_kernel(
                        "convT_dgrad", arch, batch, out_ch, in_ch, kernel, h, w, precision,
                    ));
                    kernels.push(implicit_gemm_kernel(
                        "convT_wgrad", arch, batch, in_ch, out_ch, kernel, oh, ow, precision,
                    ));
                }
            }
            if bias {
                let n_out = batch * out_ch * oh * ow;
                kernels.push(match pass {
                    Pass::Forward => ew_kernel("conv_bias", n_out, 1.0, 2.0, precision),
                    Pass::Backward => ew_kernel("conv_bias_grad", n_out, 1.0, 1.0, precision),
                });
            }
            kernels
        }
        _ => unreachable!("lower_conv called on non-conv op"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn conv_op(in_ch: usize, out_ch: usize, kernel: usize, stride: usize, image: usize) -> Op {
        Op::new(
            "conv",
            OpKind::Conv2d {
                in_ch,
                out_ch,
                kernel,
                stride,
                padding: kernel / 2,
                bias: false,
            },
            vec![32, in_ch, image, image],
        )
    }

    #[test]
    fn algo_selection_is_arch_dependent() {
        assert_eq!(select_algo(Arch::Pascal, 256, 256, 3, 1), ConvAlgo::ImplicitGemm);
        assert_eq!(select_algo(Arch::Volta, 256, 256, 3, 1), ConvAlgo::Winograd);
        assert_eq!(select_algo(Arch::Turing, 256, 256, 3, 1), ConvAlgo::Winograd);
        // 1×1 and strided convs never use Winograd.
        assert_eq!(select_algo(Arch::Volta, 256, 256, 1, 1), ConvAlgo::ImplicitGemm);
        assert_eq!(select_algo(Arch::Volta, 256, 256, 3, 2), ConvAlgo::ImplicitGemm);
        // Thin channels never use Winograd.
        assert_eq!(select_algo(Arch::Volta, 3, 64, 3, 1), ConvAlgo::ImplicitGemm);
    }

    #[test]
    fn winograd_reduces_flops_vs_implicit_gemm() {
        let op = conv_op(256, 256, 3, 1, 28);
        let volta = lower_conv(&op, Arch::Volta, Precision::Fp32, Pass::Forward);
        let pascal = lower_conv(&op, Arch::Pascal, Precision::Fp32, Pass::Forward);
        assert!(volta[0].name.starts_with("winograd"));
        assert!(pascal[0].name.starts_with("implicit_gemm"));
        assert!(volta[0].flops < pascal[0].flops, "Winograd must save FLOPs");
        assert!(volta[0].flops > 0.3 * pascal[0].flops);
    }

    #[test]
    fn backward_has_dgrad_and_wgrad() {
        let op = conv_op(64, 128, 3, 2, 56);
        let bwd = lower_conv(&op, Arch::Pascal, Precision::Fp32, Pass::Backward);
        assert_eq!(bwd.len(), 2);
        assert!(bwd[0].name.contains("dgrad"));
        assert!(bwd[1].name.contains("wgrad"));
    }

    #[test]
    fn one_by_one_conv_flops_match_gemm() {
        let op = conv_op(64, 256, 1, 1, 56);
        let k = &lower_conv(&op, Arch::Volta, Precision::Fp32, Pass::Forward)[0];
        // 2 · N·H·W · C_in · C_out
        assert_eq!(k.flops, 2.0 * (32 * 56 * 56) as f64 * 64.0 * 256.0);
    }

    #[test]
    fn conv_transpose_spatially_expands() {
        let op = Op::new(
            "convT",
            OpKind::ConvTranspose2d {
                in_ch: 512,
                out_ch: 256,
                kernel: 4,
                stride: 2,
                padding: 1,
                bias: false,
            },
            vec![64, 512, 8, 8],
        );
        let k = &lower_conv(&op, Arch::Turing, Precision::Fp32, Pass::Forward)[0];
        // Output 16×16: flops = 2·(64·16·16)·512·256·16.
        assert_eq!(k.flops, 2.0 * (64 * 16 * 16) as f64 * (512 * 256 * 16) as f64);
        assert!(k.name.contains("convT_fwd"));
    }

    #[test]
    fn bias_adds_an_elementwise_kernel() {
        let mut op = conv_op(64, 64, 3, 1, 28);
        if let OpKind::Conv2d { ref mut bias, .. } = op.kind {
            *bias = true;
        }
        let fwd = lower_conv(&op, Arch::Volta, Precision::Fp32, Pass::Forward);
        assert_eq!(fwd.len(), 2);
        assert_eq!(fwd[1].name, "conv_bias");
    }

    #[test]
    fn deterministic_lowering() {
        let op = conv_op(128, 128, 3, 1, 14);
        let a = lower_conv(&op, Arch::Turing, Precision::Fp32, Pass::Forward);
        let b = lower_conv(&op, Arch::Turing, Precision::Fp32, Pass::Forward);
        assert_eq!(a[0].name, b[0].name);
        assert_eq!(a[0].flops, b[0].flops);
        assert_eq!(a[0].dram_bytes, b[0].dram_bytes);
    }
}
