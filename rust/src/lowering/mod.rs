//! Operation → GPU-kernel lowering.
//!
//! This substrate plays the role of cuDNN/cuBLAS in the paper: it decides
//! *which kernels* implement each DNN operation on a given GPU
//! architecture, and with what launch configuration, FLOP count, and DRAM
//! traffic. Two properties matter for reproducing Habitat faithfully:
//!
//! 1. **Kernel-alike ops** (elementwise, normalization, pooling, …) lower
//!    to the *same* kernels on every architecture — only the hardware
//!    changes. Wave scaling's core assumption (§3.3) holds for them.
//! 2. **Kernel-varying ops** (conv2d, lstm, bmm, linear) lower to
//!    *architecture-specific* kernels: different algorithms (implicit GEMM
//!    vs. Winograd convolution, standard vs. persistent RNN cells) and
//!    different tile shapes per generation — reproducing the cuDNN/cuBLAS
//!    behaviour that motivates the paper's MLP predictors (§3.2, [44, 75]).
//!
//! The lowering is deterministic: the same (op, arch, precision) always
//! produces the same kernels, mirroring deterministic cuDNN heuristics.

pub mod conv;
pub mod elementwise;
pub mod gemm;
pub mod rnn;


use crate::device::{Arch, LaunchConfig};
use crate::opgraph::{Op, OpKind};

/// Numeric precision of a training iteration.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum Precision {
    /// FP32 everywhere (the paper's main evaluation).
    #[default]
    Fp32,
    /// Automatic mixed precision: FP16 storage + tensor-core matmuls where
    /// the architecture has them (§6.1.2).
    Amp,
}

impl Precision {
    /// Bytes per element for activation/weight storage.
    pub fn elem_bytes(self) -> f64 {
        match self {
            Precision::Fp32 => 4.0,
            Precision::Amp => 2.0,
        }
    }
}

/// Forward or backward half of the training step.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Pass {
    Forward,
    Backward,
}

/// A lowered GPU kernel: everything the simulator and wave scaling need.
/// This corresponds to what the paper records per kernel via CUPTI:
/// launch configuration plus the metrics needed for arithmetic intensity
/// (FLOP count, DRAM bytes).
#[derive(Debug, Clone)]
pub struct Kernel {
    /// Kernel "symbol name" — encodes the selected algorithm and tile,
    /// e.g. `volta_sgemm_128x128` or `winograd_fwd_3x3`.
    pub name: String,
    pub launch: LaunchConfig,
    /// Total floating-point operations.
    pub flops: f64,
    /// DRAM bytes moved (after the lowering's cache-reuse estimate).
    pub dram_bytes: f64,
    /// Whether the kernel can use tensor cores under AMP.
    pub tensor_core_eligible: bool,
}

impl Kernel {
    /// Arithmetic intensity in FLOP/byte — fixed per kernel (§4.2).
    pub fn arith_intensity(&self) -> f64 {
        if self.dram_bytes <= 0.0 {
            f64::INFINITY
        } else {
            self.flops / self.dram_bytes
        }
    }
}

/// Lower one operation for one pass on one architecture.
///
/// The returned kernels execute sequentially (one CUDA stream), matching
/// how PyTorch dispatches training ops.
pub fn lower(op: &Op, arch: Arch, precision: Precision, pass: Pass) -> Vec<Kernel> {
    match &op.kind {
        OpKind::Conv2d { .. } | OpKind::ConvTranspose2d { .. } => {
            conv::lower_conv(op, arch, precision, pass)
        }
        OpKind::Linear { .. } | OpKind::BatchedMatmul { .. } => {
            gemm::lower_dense(op, arch, precision, pass)
        }
        OpKind::Lstm { .. } => rnn::lower_lstm(op, arch, precision, pass),
        _ => elementwise::lower_simple(op, arch, precision, pass),
    }
}

/// Lower a whole graph: per-op forward and backward kernel lists.
/// The backward pass is walked in reverse execution order, as autograd
/// would replay it.
pub fn lower_graph(
    graph: &crate::Graph,
    arch: Arch,
    precision: Precision,
) -> Vec<(usize, Pass, Vec<Kernel>)> {
    let mut out = Vec::with_capacity(graph.ops.len() * 2);
    for (i, op) in graph.ops.iter().enumerate() {
        out.push((i, Pass::Forward, lower(op, arch, precision, Pass::Forward)));
    }
    for (i, op) in graph.ops.iter().enumerate().rev() {
        let kernels = lower(op, arch, precision, Pass::Backward);
        if !kernels.is_empty() {
            out.push((i, Pass::Backward, kernels));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::opgraph::{EwKind, Op, OpKind};

    fn relu(n: usize) -> Op {
        Op::new("relu", OpKind::Elementwise { kind: EwKind::Relu }, vec![n])
    }

    #[test]
    fn kernel_alike_ops_lower_identically_across_archs() {
        let op = relu(1 << 20);
        for pass in [Pass::Forward, Pass::Backward] {
            let a = lower(&op, Arch::Pascal, Precision::Fp32, pass);
            let b = lower(&op, Arch::Volta, Precision::Fp32, pass);
            let c = lower(&op, Arch::Turing, Precision::Fp32, pass);
            assert_eq!(a.len(), b.len());
            for ((ka, kb), kc) in a.iter().zip(&b).zip(&c) {
                assert_eq!(ka.name, kb.name, "kernel-alike must keep names");
                assert_eq!(ka.flops, kb.flops);
                assert_eq!(ka.dram_bytes, kc.dram_bytes);
                assert_eq!(ka.launch, kb.launch);
            }
        }
    }

    #[test]
    fn kernel_varying_ops_differ_across_archs() {
        let op = Op::new(
            "conv",
            OpKind::Conv2d {
                in_ch: 256,
                out_ch: 256,
                kernel: 3,
                stride: 1,
                padding: 1,
                bias: false,
            },
            vec![32, 256, 28, 28],
        );
        let pascal = lower(&op, Arch::Pascal, Precision::Fp32, Pass::Forward);
        let volta = lower(&op, Arch::Volta, Precision::Fp32, Pass::Forward);
        // Pascal picks implicit GEMM, Volta picks Winograd for 3×3/s1.
        assert_ne!(pascal[0].name, volta[0].name);
    }

    #[test]
    fn arith_intensity_positive_finite_for_gemm() {
        let op = Op::new(
            "fc",
            OpKind::Linear {
                in_features: 1024,
                out_features: 1024,
                bias: true,
            },
            vec![64, 1024],
        );
        for k in lower(&op, Arch::Volta, Precision::Fp32, Pass::Forward) {
            assert!(k.arith_intensity().is_finite());
            assert!(k.arith_intensity() > 0.0);
        }
    }

    #[test]
    fn graph_lowering_walks_backward_in_reverse() {
        let mut g = crate::Graph::new("toy", 4);
        g.push(relu(100));
        g.push(Op::new(
            "fc",
            OpKind::Linear {
                in_features: 8,
                out_features: 8,
                bias: false,
            },
            vec![4, 8],
        ));
        let lowered = lower_graph(&g, Arch::Volta, Precision::Fp32);
        let fwd: Vec<usize> = lowered
            .iter()
            .filter(|(_, p, _)| *p == Pass::Forward)
            .map(|(i, _, _)| *i)
            .collect();
        let bwd: Vec<usize> = lowered
            .iter()
            .filter(|(_, p, _)| *p == Pass::Backward)
            .map(|(i, _, _)| *i)
            .collect();
        assert_eq!(fwd, vec![0, 1]);
        assert_eq!(bwd, vec![1, 0]);
    }
}
