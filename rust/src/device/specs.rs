//! Device handles and the built-in GPU specifications.
//!
//! The six GPUs of the paper's evaluation (Table 2) ship as **seed
//! entries** of the process-wide [`super::registry::DeviceRegistry`];
//! additional GPUs can be registered at runtime (e.g. through the
//! service's `register_device` request) without recompiling anything.
//! All numbers for the built-ins come from public NVIDIA datasheets /
//! whitepapers; rental prices are the paper's Table 2 (Google Cloud
//! us-central1, June 2021).

/// GPU micro-architecture generation. The paper spans three; runtime-
/// registered devices pick the closest match (it drives occupancy
/// limits and tensor-core eligibility).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Arch {
    Pascal,
    Volta,
    Turing,
}

impl Arch {
    /// Architectures ordered by release; used by the kernel-selection
    /// substrate (newer arch ⇒ newer kernel library dispatch).
    pub fn generation(self) -> u32 {
        match self {
            Arch::Pascal => 0,
            Arch::Volta => 1,
            Arch::Turing => 2,
        }
    }

    /// Whether the architecture has tensor cores (mixed-precision MMA).
    pub fn has_tensor_cores(self) -> bool {
        !matches!(self, Arch::Pascal)
    }

    /// Parse from a lowercase name (used by `register_device`).
    pub fn parse(s: &str) -> Option<Arch> {
        match s.to_ascii_lowercase().as_str() {
            "pascal" => Some(Arch::Pascal),
            "volta" => Some(Arch::Volta),
            "turing" => Some(Arch::Turing),
            _ => None,
        }
    }
}

impl std::fmt::Display for Arch {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{self:?}")
    }
}

/// An interned device handle: a small index into the process-wide
/// [`super::registry::DeviceRegistry`]. Built-in GPUs occupy the first
/// six slots (in the paper's Table 2 order); devices registered at
/// runtime follow. `Copy + Ord + Hash`, so it keys caches and dense
/// per-device tables exactly like the old copy-enum did — but the set
/// of devices is open.
#[derive(Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct Device(pub(crate) u32);

/// Alias that makes registry-handle intent explicit in signatures.
pub type DeviceId = Device;

/// The six built-in (seed) devices, in the paper's Table 2 order. This
/// is the *paper's* evaluation set — experiments and golden tests sweep
/// it. For "every device currently known" (including runtime
/// registrations) use [`super::registry::all_devices`].
pub const ALL_DEVICES: [Device; 6] = [
    Device::P4000,
    Device::P100,
    Device::V100,
    Device::Rtx2070,
    Device::Rtx2080Ti,
    Device::T4,
];

/// Full hardware description of one GPU.
#[derive(Debug, Clone)]
pub struct GpuSpec {
    pub device: Device,
    pub name: &'static str,
    pub arch: Arch,
    /// Streaming multiprocessors.
    pub sms: u32,
    /// CUDA cores (FP32 lanes) across the chip.
    pub cuda_cores: u32,
    /// Device memory capacity, GiB.
    pub mem_gib: f64,
    /// Peak DRAM bandwidth, GB/s (datasheet).
    pub peak_mem_bw_gbps: f64,
    /// *Achieved* DRAM bandwidth, GB/s. The paper measures this once per
    /// GPU and ships it in a config file (§3.3); we model it as a
    /// memory-technology-dependent fraction of peak (HBM2 sustains a higher
    /// fraction than GDDR).
    pub achieved_mem_bw_gbps: f64,
    /// Boost clock, MHz — the `C_i` of Eq. 1/2.
    pub boost_clock_mhz: f64,
    /// Peak FP32 throughput, TFLOP/s (datasheet).
    pub peak_fp32_tflops: f64,
    /// Peak FP16/tensor-core throughput, TFLOP/s (FP16 accumulate where
    /// applicable). Pascal has no tensor cores: this is 2× FP32 on P100
    /// (half-rate FP16 path) and ≈FP32 on P4000.
    pub peak_fp16_tflops: f64,
    /// L2 cache size, KiB — drives the simulator's DRAM-traffic reuse model.
    pub l2_cache_kib: u32,
    /// Occupancy limits (per SM).
    pub max_threads_per_sm: u32,
    pub max_blocks_per_sm: u32,
    pub regs_per_sm: u32,
    pub smem_per_sm_bytes: u32,
    /// Rental cost on Google Cloud us-central1 (paper Table 2), if offered.
    pub rental_usd_per_hr: Option<f64>,
}

impl GpuSpec {
    /// Peak FP32 throughput in FLOP/s (not TFLOP/s).
    pub fn peak_flops(&self) -> f64 {
        self.peak_fp32_tflops * 1e12
    }

    /// Achieved memory bandwidth in bytes/s.
    pub fn achieved_bw_bytes(&self) -> f64 {
        self.achieved_mem_bw_gbps * 1e9
    }

    /// Roofline ridge point `R = P / D` in FLOPs per byte (§4.2).
    pub fn ridge_point(&self) -> f64 {
        self.peak_flops() / self.achieved_bw_bytes()
    }
}

/// The built-in seed specs, indexed by [`Device::index`] of the matching
/// [`ALL_DEVICES`] entry.
pub(super) static BUILTIN_SPECS: [GpuSpec; 6] = [
    // Quadro P4000 (GP104): 14 SMs × 128 cores, 8 GiB GDDR5.
    GpuSpec {
        device: Device::P4000,
        name: "P4000",
        arch: Arch::Pascal,
        sms: 14,
        cuda_cores: 1792,
        mem_gib: 8.0,
        peak_mem_bw_gbps: 243.0,
        achieved_mem_bw_gbps: 192.0, // GDDR5 ≈ 79% of peak
        boost_clock_mhz: 1480.0,
        peak_fp32_tflops: 5.3,
        peak_fp16_tflops: 5.3, // GP104 fp16 is not a fast path
        l2_cache_kib: 2048,
        max_threads_per_sm: 2048,
        max_blocks_per_sm: 32,
        regs_per_sm: 65_536,
        smem_per_sm_bytes: 96 * 1024,
        rental_usd_per_hr: None,
    },
    // Tesla P100 PCIe 16 GiB (GP100): 56 SMs × 64 cores, HBM2.
    GpuSpec {
        device: Device::P100,
        name: "P100",
        arch: Arch::Pascal,
        sms: 56,
        cuda_cores: 3584,
        mem_gib: 16.0,
        peak_mem_bw_gbps: 732.0,
        achieved_mem_bw_gbps: 578.0, // HBM2 ≈ 79% of peak
        boost_clock_mhz: 1303.0,
        peak_fp32_tflops: 9.3,
        peak_fp16_tflops: 18.7, // GP100 half-precision 2× path
        l2_cache_kib: 4096,
        max_threads_per_sm: 2048,
        max_blocks_per_sm: 32,
        regs_per_sm: 65_536,
        smem_per_sm_bytes: 64 * 1024,
        rental_usd_per_hr: Some(1.46),
    },
    // Tesla V100 SXM2 16 GiB (GV100): 80 SMs × 64 cores, HBM2.
    GpuSpec {
        device: Device::V100,
        name: "V100",
        arch: Arch::Volta,
        sms: 80,
        cuda_cores: 5120,
        mem_gib: 16.0,
        peak_mem_bw_gbps: 900.0,
        achieved_mem_bw_gbps: 790.0, // HBM2 on Volta sustains ~88%
        boost_clock_mhz: 1530.0,
        peak_fp32_tflops: 15.7,
        peak_fp16_tflops: 125.0, // tensor cores
        l2_cache_kib: 6144,
        max_threads_per_sm: 2048,
        max_blocks_per_sm: 32,
        regs_per_sm: 65_536,
        smem_per_sm_bytes: 96 * 1024,
        rental_usd_per_hr: Some(2.48),
    },
    // GeForce RTX 2070 (TU106): 36 SMs × 64 cores, GDDR6.
    GpuSpec {
        device: Device::Rtx2070,
        name: "RTX2070",
        arch: Arch::Turing,
        sms: 36,
        cuda_cores: 2304,
        mem_gib: 8.0,
        peak_mem_bw_gbps: 448.0,
        achieved_mem_bw_gbps: 362.0, // GDDR6 ≈ 81% of peak
        boost_clock_mhz: 1620.0,
        peak_fp32_tflops: 7.5,
        peak_fp16_tflops: 59.7, // tensor cores
        l2_cache_kib: 4096,
        max_threads_per_sm: 1024, // Turing halves thread residency
        max_blocks_per_sm: 16,
        regs_per_sm: 65_536,
        smem_per_sm_bytes: 64 * 1024,
        rental_usd_per_hr: None,
    },
    // GeForce RTX 2080 Ti (TU102): 68 SMs × 64 cores, GDDR6.
    GpuSpec {
        device: Device::Rtx2080Ti,
        name: "RTX2080Ti",
        arch: Arch::Turing,
        sms: 68,
        cuda_cores: 4352,
        mem_gib: 11.0,
        peak_mem_bw_gbps: 616.0,
        achieved_mem_bw_gbps: 499.0,
        boost_clock_mhz: 1545.0,
        peak_fp32_tflops: 13.4,
        peak_fp16_tflops: 107.0, // tensor cores
        l2_cache_kib: 5632,
        max_threads_per_sm: 1024,
        max_blocks_per_sm: 16,
        regs_per_sm: 65_536,
        smem_per_sm_bytes: 64 * 1024,
        rental_usd_per_hr: None,
    },
    // Tesla T4 (TU104): 40 SMs × 64 cores, GDDR6, 70 W envelope.
    GpuSpec {
        device: Device::T4,
        name: "T4",
        arch: Arch::Turing,
        sms: 40,
        cuda_cores: 2560,
        mem_gib: 16.0,
        peak_mem_bw_gbps: 320.0,
        achieved_mem_bw_gbps: 259.0,
        // T4 is power-limited: the sustained clock is well below the
        // 1590 MHz datasheet boost. We model the sustained clock.
        boost_clock_mhz: 1350.0,
        peak_fp32_tflops: 8.1,
        peak_fp16_tflops: 65.0, // tensor cores
        l2_cache_kib: 4096,
        max_threads_per_sm: 1024,
        max_blocks_per_sm: 16,
        regs_per_sm: 65_536,
        smem_per_sm_bytes: 64 * 1024,
        rental_usd_per_hr: Some(0.35),
    },
];

// The built-in handles keep the old enum-variant names (mixed case) so
// every existing `Device::Rtx2070`-style call site still compiles.
#[allow(non_upper_case_globals)]
impl Device {
    pub const P4000: Device = Device(0);
    pub const P100: Device = Device(1);
    pub const V100: Device = Device(2);
    pub const Rtx2070: Device = Device(3);
    pub const Rtx2080Ti: Device = Device(4);
    pub const T4: Device = Device(5);

    /// Look up the full hardware spec for this device in the registry.
    pub fn spec(self) -> &'static GpuSpec {
        super::registry::spec_of(self)
    }

    /// Whether this is one of the six built-in (paper Table 2) devices.
    pub fn is_builtin(self) -> bool {
        (self.0 as usize) < ALL_DEVICES.len()
    }

    /// Short stable identifier (used in CSV output and the CLI).
    pub fn id(self) -> &'static str {
        self.spec().name
    }

    /// Position of this device in the registry — the index used by the
    /// dense per-device tables of [`crate::plan::AnalyzedPlan`]. For the
    /// built-ins this is also the position in [`ALL_DEVICES`].
    pub fn index(self) -> usize {
        self.0 as usize
    }

    /// Parse a device from its short name (case-insensitive), consulting
    /// the registry — runtime-registered devices parse too.
    pub fn parse(s: &str) -> Option<Device> {
        super::registry::find(s)
    }
}

impl std::fmt::Display for Device {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match super::registry::try_spec(*self) {
            Some(s) => write!(f, "{}", s.name),
            None => write!(f, "device#{}", self.0),
        }
    }
}

impl std::fmt::Debug for Device {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        // Print the name (like the old enum's derived Debug did), not
        // the raw index.
        std::fmt::Display::fmt(self, f)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn six_devices_with_unique_names() {
        let mut names: Vec<_> = ALL_DEVICES.iter().map(|d| d.id()).collect();
        names.sort();
        names.dedup();
        assert_eq!(names.len(), 6);
    }

    #[test]
    fn index_is_the_position_in_all_devices() {
        for (i, d) in ALL_DEVICES.into_iter().enumerate() {
            assert_eq!(d.index(), i, "{d}");
        }
    }

    #[test]
    fn paper_table2_sm_counts() {
        assert_eq!(Device::P4000.spec().sms, 14);
        assert_eq!(Device::P100.spec().sms, 56);
        assert_eq!(Device::V100.spec().sms, 80);
        assert_eq!(Device::Rtx2070.spec().sms, 36);
        assert_eq!(Device::Rtx2080Ti.spec().sms, 68);
        assert_eq!(Device::T4.spec().sms, 40);
    }

    #[test]
    fn paper_table2_memory_and_prices() {
        assert_eq!(Device::P4000.spec().mem_gib, 8.0);
        assert_eq!(Device::T4.spec().mem_gib, 16.0);
        assert_eq!(Device::P100.spec().rental_usd_per_hr, Some(1.46));
        assert_eq!(Device::V100.spec().rental_usd_per_hr, Some(2.48));
        assert_eq!(Device::T4.spec().rental_usd_per_hr, Some(0.35));
        assert_eq!(Device::Rtx2080Ti.spec().rental_usd_per_hr, None);
    }

    #[test]
    fn achieved_bw_below_peak() {
        for d in ALL_DEVICES {
            let s = d.spec();
            assert!(s.achieved_mem_bw_gbps < s.peak_mem_bw_gbps);
            assert!(s.achieved_mem_bw_gbps > 0.5 * s.peak_mem_bw_gbps);
        }
    }

    #[test]
    fn ridge_points_plausible() {
        // FP32 ridge points for these GPUs fall between ~15 and ~40 FLOP/B.
        for d in ALL_DEVICES {
            let r = d.spec().ridge_point();
            assert!((10.0..60.0).contains(&r), "{d}: R={r}");
        }
    }

    #[test]
    fn parse_roundtrip_and_aliases() {
        for d in ALL_DEVICES {
            assert_eq!(Device::parse(d.id()), Some(d));
        }
        assert_eq!(Device::parse("2080ti"), Some(Device::Rtx2080Ti));
        assert_eq!(Device::parse("v100"), Some(Device::V100));
        assert_eq!(Device::parse("a100"), None);
    }

    #[test]
    fn turing_has_tensor_cores_pascal_does_not() {
        assert!(!Arch::Pascal.has_tensor_cores());
        assert!(Arch::Volta.has_tensor_cores());
        assert!(Arch::Turing.has_tensor_cores());
    }

    #[test]
    fn debug_and_display_print_the_name() {
        assert_eq!(format!("{}", Device::V100), "V100");
        assert_eq!(format!("{:?}", Device::T4), "T4");
    }
}
