//! The process-wide device registry — the open-world replacement for
//! the old `Device` copy-enum.
//!
//! Habitat's pitch is predicting performance for *a GPU the user doesn't
//! have*; a closed enum of six 2021-era GPUs goes stale the day a new
//! accelerator ships. The registry keeps the six paper GPUs as **seed
//! entries** (always present, always at indices `0..6`, so every dense
//! per-device table and cache key built against them is stable) and lets
//! callers [`register`] new device specs at runtime — from the CLI, from
//! library code, or over the wire via the service's `register_device`
//! request. A freshly registered device is immediately usable everywhere
//! a built-in is: as a prediction origin or destination, in `rank`
//! fan-outs, in the cluster scheduler, and in dataset generation.
//!
//! Interning: a [`Device`] is just an index into this registry.
//! Registered specs are leaked (`Box::leak`) so `Device::spec()` can
//! keep returning `&'static GpuSpec` exactly as it always has — devices
//! are registered a handful of times per process lifetime, so the leak
//! is bounded and intentional. Lookups for built-in devices never touch
//! the lock.

use std::sync::{OnceLock, RwLock};

use super::specs::{Arch, Device, GpuSpec, ALL_DEVICES, BUILTIN_SPECS};

/// Short-name aliases accepted by [`find`] in addition to spec names.
const ALIASES: [(&str, Device); 2] = [("2070", Device::Rtx2070), ("2080ti", Device::Rtx2080Ti)];

/// Hard cap on registry size. Each registration leaks one `GpuSpec`
/// (that's the interning design) and joins every default `rank`
/// fan-out and every plan's dense tables, so an unauthenticated wire
/// client must not be able to grow the registry without bound.
pub const MAX_DEVICES: usize = 1024;

/// Runtime-registered specs (beyond the six built-ins), in id order.
fn extra() -> &'static RwLock<Vec<&'static GpuSpec>> {
    static EXTRA: OnceLock<RwLock<Vec<&'static GpuSpec>>> = OnceLock::new();
    EXTRA.get_or_init(|| RwLock::new(Vec::new()))
}

/// Number of devices currently registered (built-ins included). Dense
/// per-device tables (e.g. [`crate::plan::AnalyzedPlan`]) snapshot this
/// at build time.
pub fn device_count() -> usize {
    ALL_DEVICES.len() + extra().read().unwrap().len()
}

/// Every registered device, in id (= index) order: the six built-ins
/// first, then runtime registrations. This is the open-world analogue of
/// [`ALL_DEVICES`] and the default destination set of `rank`.
pub fn all_devices() -> Vec<Device> {
    (0..device_count() as u32).map(Device).collect()
}

/// Every registered device name, in id order (for error messages).
pub fn device_names() -> Vec<&'static str> {
    let mut names: Vec<&'static str> = BUILTIN_SPECS.iter().map(|s| s.name).collect();
    names.extend(extra().read().unwrap().iter().map(|s| s.name));
    names
}

/// Spec lookup; `None` for an id this registry never minted.
pub fn try_spec(d: Device) -> Option<&'static GpuSpec> {
    let i = d.index();
    if i < ALL_DEVICES.len() {
        Some(&BUILTIN_SPECS[i])
    } else {
        extra().read().unwrap().get(i - ALL_DEVICES.len()).copied()
    }
}

/// Spec lookup for a registry-minted id (panics otherwise — ids only
/// come from this registry, so this is unreachable in correct code).
pub fn spec_of(d: Device) -> &'static GpuSpec {
    try_spec(d).unwrap_or_else(|| panic!("device id {} is not in the registry", d.index()))
}

/// Case-insensitive name (or alias) lookup.
pub fn find(name: &str) -> Option<Device> {
    let lower = name.to_ascii_lowercase();
    for (i, s) in BUILTIN_SPECS.iter().enumerate() {
        if s.name.to_ascii_lowercase() == lower {
            return Some(ALL_DEVICES[i]);
        }
    }
    for (alias, d) in ALIASES {
        if alias == lower {
            return Some(d);
        }
    }
    let extras = extra().read().unwrap();
    for (i, s) in extras.iter().enumerate() {
        if s.name.to_ascii_lowercase() == lower {
            return Some(Device((ALL_DEVICES.len() + i) as u32));
        }
    }
    None
}

/// A new device description, as supplied by `register_device` (wire or
/// library). Only the fields a datasheet front page carries are
/// required; everything else gets an architecture-informed default.
#[derive(Debug, Clone)]
pub struct NewDevice {
    /// Short unique name (e.g. `"A100"`); 1–64 chars of
    /// `[A-Za-z0-9._-]`, compared case-insensitively.
    pub name: String,
    /// Streaming multiprocessors.
    pub sms: u32,
    /// Boost (sustained) clock, MHz.
    pub clock_mhz: f64,
    /// Peak DRAM bandwidth, GB/s.
    pub mem_bw_gbps: f64,
    /// Peak FP32 throughput, TFLOP/s.
    pub fp32_tflops: f64,
    /// Whether the chip has tensor cores (selects the default arch).
    pub tensor_cores: bool,
    /// Rental price, $/hr, if offered (drives cost-normalized ranking).
    pub usd_per_hr: Option<f64>,
    /// Explicit architecture; default Volta-like with tensor cores,
    /// Pascal-like without.
    pub arch: Option<Arch>,
    /// Achieved DRAM bandwidth, GB/s; default 80% of peak.
    pub achieved_bw_gbps: Option<f64>,
    /// Memory capacity, GiB; default 16.
    pub mem_gib: Option<f64>,
    /// Peak FP16/tensor throughput, TFLOP/s; default 8× FP32 with
    /// tensor cores, else = FP32.
    pub fp16_tflops: Option<f64>,
    /// CUDA cores; default 64 per SM.
    pub cuda_cores: Option<u32>,
    /// L2 cache, KiB; default 4096.
    pub l2_kib: Option<u32>,
}

impl NewDevice {
    /// Minimal description: everything else defaulted.
    pub fn new(
        name: &str,
        sms: u32,
        clock_mhz: f64,
        mem_bw_gbps: f64,
        fp32_tflops: f64,
        tensor_cores: bool,
    ) -> Self {
        NewDevice {
            name: name.to_string(),
            sms,
            clock_mhz,
            mem_bw_gbps,
            fp32_tflops,
            tensor_cores,
            usd_per_hr: None,
            arch: None,
            achieved_bw_gbps: None,
            mem_gib: None,
            fp16_tflops: None,
            cuda_cores: None,
            l2_kib: None,
        }
    }
}

/// Why a [`register`] call was refused. Split so the wire layer can map
/// each to a distinct structured error code.
#[derive(Debug)]
pub enum RegisterError {
    /// The name is taken by a device with a *different* spec.
    Conflict(String),
    /// The description failed validation.
    Invalid(String),
}

impl std::fmt::Display for RegisterError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RegisterError::Conflict(m) => write!(f, "{m}"),
            RegisterError::Invalid(m) => write!(f, "{m}"),
        }
    }
}

impl std::error::Error for RegisterError {}

fn validate(d: &NewDevice) -> Result<(), RegisterError> {
    let bad = |m: String| Err(RegisterError::Invalid(m));
    if d.name.is_empty() || d.name.len() > 64 {
        return bad("device name must be 1..=64 characters".into());
    }
    if !d.name.chars().all(|c| c.is_ascii_alphanumeric() || matches!(c, '.' | '_' | '-')) {
        return bad(format!("device name {:?} has characters outside [A-Za-z0-9._-]", d.name));
    }
    if d.sms == 0 {
        return bad("sms must be positive".into());
    }
    for (field, v) in [
        ("clock_mhz", d.clock_mhz),
        ("mem_bw_gbps", d.mem_bw_gbps),
        ("fp32_tflops", d.fp32_tflops),
    ] {
        if !(v.is_finite() && v > 0.0) {
            return bad(format!("{field} must be a positive number"));
        }
    }
    if let Some(a) = d.achieved_bw_gbps {
        if !(a.is_finite() && a > 0.0 && a <= d.mem_bw_gbps) {
            return bad("achieved_bw_gbps must be in (0, mem_bw_gbps]".into());
        }
    }
    for (field, v) in [("mem_gib", d.mem_gib), ("fp16_tflops", d.fp16_tflops)] {
        if let Some(v) = v {
            if !(v.is_finite() && v > 0.0) {
                return bad(format!("{field} must be a positive number"));
            }
        }
    }
    if let Some(p) = d.usd_per_hr {
        if !(p.is_finite() && p > 0.0) {
            return bad("usd_per_hr must be a positive number".into());
        }
    }
    if let Some(arch) = d.arch {
        if arch.has_tensor_cores() != d.tensor_cores {
            return bad(format!(
                "arch {arch} contradicts tensor_cores={}",
                d.tensor_cores
            ));
        }
    }
    Ok(())
}

/// Resolve a [`NewDevice`] into a full [`GpuSpec`] (defaults applied).
/// `device` and `name` are placeholders until interning.
fn resolve(d: &NewDevice) -> GpuSpec {
    let arch = d.arch.unwrap_or(if d.tensor_cores { Arch::Volta } else { Arch::Pascal });
    // Occupancy limits follow the architecture generation (Turing halves
    // thread/block residency; Pascal/Volta share the classic limits).
    let (max_threads_per_sm, max_blocks_per_sm) = match arch {
        Arch::Turing => (1024, 16),
        Arch::Pascal | Arch::Volta => (2048, 32),
    };
    let fp32 = d.fp32_tflops;
    GpuSpec {
        device: Device(u32::MAX), // patched at interning
        name: "",                 // patched at interning
        arch,
        sms: d.sms,
        cuda_cores: d.cuda_cores.unwrap_or(d.sms * 64),
        mem_gib: d.mem_gib.unwrap_or(16.0),
        peak_mem_bw_gbps: d.mem_bw_gbps,
        achieved_mem_bw_gbps: d.achieved_bw_gbps.unwrap_or(0.8 * d.mem_bw_gbps),
        boost_clock_mhz: d.clock_mhz,
        peak_fp32_tflops: fp32,
        peak_fp16_tflops: d
            .fp16_tflops
            .unwrap_or(if arch.has_tensor_cores() { 8.0 * fp32 } else { fp32 }),
        l2_cache_kib: d.l2_kib.unwrap_or(4096),
        max_threads_per_sm,
        max_blocks_per_sm,
        regs_per_sm: 65_536,
        smem_per_sm_bytes: 64 * 1024,
        rental_usd_per_hr: d.usd_per_hr,
    }
}

/// Two specs describe the same hardware (used for idempotent re-registration).
fn same_hardware(a: &GpuSpec, b: &GpuSpec) -> bool {
    a.arch == b.arch
        && a.sms == b.sms
        && a.cuda_cores == b.cuda_cores
        && a.mem_gib == b.mem_gib
        && a.peak_mem_bw_gbps == b.peak_mem_bw_gbps
        && a.achieved_mem_bw_gbps == b.achieved_mem_bw_gbps
        && a.boost_clock_mhz == b.boost_clock_mhz
        && a.peak_fp32_tflops == b.peak_fp32_tflops
        && a.peak_fp16_tflops == b.peak_fp16_tflops
        && a.l2_cache_kib == b.l2_cache_kib
        && a.rental_usd_per_hr == b.rental_usd_per_hr
}

/// Register a new device, returning its interned handle.
///
/// Idempotent: re-registering an identical description returns the
/// existing handle (so clients can blindly replay registrations after a
/// reconnect). A name collision with a *different* spec — including the
/// built-in names and aliases — is a [`RegisterError::Conflict`].
pub fn register(desc: &NewDevice) -> Result<Device, RegisterError> {
    validate(desc)?;
    let resolved = resolve(desc);
    let lower = desc.name.to_ascii_lowercase();

    // Built-in names and aliases are reserved, idempotency aside.
    for (i, s) in BUILTIN_SPECS.iter().enumerate() {
        if s.name.to_ascii_lowercase() == lower {
            return if same_hardware(s, &resolved) {
                Ok(ALL_DEVICES[i])
            } else {
                Err(RegisterError::Conflict(format!(
                    "device name {:?} is taken by a built-in device with a different spec",
                    desc.name
                )))
            };
        }
    }
    if ALIASES.iter().any(|(alias, _)| *alias == lower) {
        return Err(RegisterError::Conflict(format!(
            "device name {:?} is a reserved alias",
            desc.name
        )));
    }

    // Hold the write lock across the lookup so two racing registrations
    // of the same name can't both insert.
    let mut extras = extra().write().unwrap();
    for (i, s) in extras.iter().enumerate() {
        if s.name.to_ascii_lowercase() == lower {
            return if same_hardware(s, &resolved) {
                Ok(Device((ALL_DEVICES.len() + i) as u32))
            } else {
                Err(RegisterError::Conflict(format!(
                    "device name {:?} is already registered with a different spec",
                    desc.name
                )))
            };
        }
    }

    if ALL_DEVICES.len() + extras.len() >= MAX_DEVICES {
        return Err(RegisterError::Invalid(format!(
            "device registry is full ({MAX_DEVICES} devices)"
        )));
    }
    let id = Device((ALL_DEVICES.len() + extras.len()) as u32);
    let mut spec = resolved;
    spec.device = id;
    spec.name = Box::leak(desc.name.clone().into_boxed_str());
    extras.push(Box::leak(Box::new(spec)));
    Ok(id)
}

#[cfg(test)]
mod tests {
    use super::*;

    // NOTE: the registry is process-global and `cargo test` runs tests
    // concurrently in one process — every test here uses names no other
    // test registers, asserts "contains"-style rather than exact
    // lengths, and never registers names other tests expect to be
    // unknown (e.g. "a100").

    #[test]
    fn builtins_are_seeded_and_lock_free_lookups_work() {
        assert!(device_count() >= ALL_DEVICES.len());
        for d in ALL_DEVICES {
            assert!(d.is_builtin());
            assert_eq!(spec_of(d).device, d);
            assert_eq!(find(d.id()), Some(d));
        }
        assert!(try_spec(Device(9999)).is_none());
    }

    #[test]
    fn register_then_parse_spec_and_enumerate() {
        let d = register(&NewDevice {
            usd_per_hr: Some(1.10),
            mem_gib: Some(24.0),
            ..NewDevice::new("sim-L4", 58, 2040.0, 300.0, 30.3, true)
        })
        .unwrap();
        assert!(!d.is_builtin());
        assert_eq!(Device::parse("sim-l4"), Some(d), "parse is case-insensitive");
        let s = d.spec();
        assert_eq!(s.name, "sim-L4");
        assert_eq!(s.sms, 58);
        assert_eq!(s.arch, Arch::Volta, "tensor cores default to Volta-like");
        assert_eq!(s.rental_usd_per_hr, Some(1.10));
        assert_eq!(s.achieved_mem_bw_gbps, 0.8 * 300.0);
        assert_eq!(s.peak_fp16_tflops, 8.0 * 30.3);
        assert!(all_devices().contains(&d));
        assert!(device_names().contains(&"sim-L4"));
        assert_eq!(format!("{d}"), "sim-L4");
    }

    #[test]
    fn reregistration_is_idempotent_and_conflicts_are_refused() {
        let desc = NewDevice::new("sim-idem", 10, 1000.0, 100.0, 5.0, false);
        let a = register(&desc).unwrap();
        let b = register(&desc).unwrap();
        assert_eq!(a, b, "identical re-registration returns the same handle");
        assert_eq!(a.spec().arch, Arch::Pascal, "no tensor cores defaults to Pascal-like");

        let clash = NewDevice::new("SIM-IDEM", 12, 1000.0, 100.0, 5.0, false);
        assert!(matches!(register(&clash), Err(RegisterError::Conflict(_))));
    }

    #[test]
    fn builtin_names_and_aliases_are_reserved() {
        let clash = NewDevice::new("V100", 80, 1530.0, 900.0, 15.7, true);
        assert!(matches!(register(&clash), Err(RegisterError::Conflict(_))));
        let alias = NewDevice::new("2080ti", 68, 1545.0, 616.0, 13.4, true);
        assert!(matches!(register(&alias), Err(RegisterError::Conflict(_))));
    }

    #[test]
    fn validation_rejects_nonsense() {
        let ok = |name: &str| NewDevice::new(name, 8, 1000.0, 100.0, 5.0, false);
        assert!(matches!(register(&ok("")), Err(RegisterError::Invalid(_))));
        assert!(matches!(
            register(&NewDevice::new("bad name", 8, 1000.0, 100.0, 5.0, false)),
            Err(RegisterError::Invalid(_))
        ));
        assert!(matches!(
            register(&NewDevice::new("sim-zero-sms", 0, 1000.0, 100.0, 5.0, false)),
            Err(RegisterError::Invalid(_))
        ));
        assert!(matches!(
            register(&NewDevice::new("sim-neg-clock", 8, -1.0, 100.0, 5.0, false)),
            Err(RegisterError::Invalid(_))
        ));
        assert!(matches!(
            register(&NewDevice {
                achieved_bw_gbps: Some(200.0), // above peak
                ..ok("sim-bad-bw")
            }),
            Err(RegisterError::Invalid(_))
        ));
        assert!(matches!(
            register(&NewDevice {
                arch: Some(Arch::Turing), // contradicts tensor_cores=false
                ..ok("sim-bad-arch")
            }),
            Err(RegisterError::Invalid(_))
        ));
    }

    #[test]
    fn registered_device_flows_through_prediction_end_to_end() {
        // The whole point: a runtime-registered GPU is a first-class
        // origin *and* destination with no other code changes.
        let d = register(&NewDevice {
            mem_gib: Some(40.0),
            usd_per_hr: Some(2.0),
            ..NewDevice::new("sim-a40e", 84, 1740.0, 696.0, 37.4, true)
        })
        .unwrap();
        let graph = crate::models::by_name("mlp", 16).unwrap();
        let trace = crate::tracker::OperationTracker::new(d).track(&graph);
        assert_eq!(trace.origin, d);
        assert!(trace.run_time_ms() > 0.0);
        let pred = crate::predict::HybridPredictor::wave_only().predict(&trace, Device::V100);
        assert!(pred.run_time_ms() > 0.0);
        let back = crate::predict::HybridPredictor::wave_only()
            .predict(&crate::tracker::OperationTracker::new(Device::V100).track(&graph), d);
        assert!(back.run_time_ms() > 0.0);
        assert!(crate::cost::cost_normalized_throughput(d, 100.0).is_some());
    }
}
