//! GPU device database and execution-model parameters.
//!
//! This is the substrate that replaces the paper's six physical GPUs
//! (Table 2): a datasheet-accurate specification for each device, plus the
//! per-architecture occupancy limits the CUDA occupancy calculator needs to
//! compute *wave sizes* (`W_i` in Eq. 1/2 of the paper).
//!
//! The device set is **open**: the six paper GPUs are seed entries of
//! the process-wide [`registry`], and new devices can be registered at
//! runtime ([`registry::register`], or the service's `register_device`
//! request). A [`Device`] is an interned registry handle.

pub mod occupancy;
pub mod registry;
pub mod specs;

pub use occupancy::{blocks_per_sm, occupancy_fraction, wave_size, LaunchConfig};
pub use registry::{NewDevice, RegisterError};
pub use specs::{Arch, Device, DeviceId, GpuSpec, ALL_DEVICES};
