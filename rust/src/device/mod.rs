//! GPU device database and execution-model parameters.
//!
//! This is the substrate that replaces the paper's six physical GPUs
//! (Table 2): a datasheet-accurate specification for each device, plus the
//! per-architecture occupancy limits the CUDA occupancy calculator needs to
//! compute *wave sizes* (`W_i` in Eq. 1/2 of the paper).

pub mod occupancy;
pub mod specs;

pub use occupancy::{blocks_per_sm, occupancy_fraction, wave_size, LaunchConfig};
pub use specs::{Arch, Device, GpuSpec, ALL_DEVICES};
