//! CUDA thread-block occupancy calculator.
//!
//! Wave scaling (§3.3 of the paper) needs `W_i`, the number of thread
//! blocks in one *wave* of execution on GPU *i*: the number of blocks that
//! can be resident simultaneously across the chip. The paper computes it
//! with the occupancy calculator from the CUDA Toolkit; this module
//! reimplements that calculation from the architecture limits in
//! [`crate::device::GpuSpec`].
//!
//! Blocks per SM is the minimum over four constraints:
//! 1. the SM's hard block limit,
//! 2. the SM's thread residency limit,
//! 3. the register file (registers are allocated per-warp with a
//!    granularity of 256 registers),
//! 4. shared memory (allocated per-block with 256-byte granularity).


use crate::device::GpuSpec;

/// Kernel launch configuration — what CUPTI would report per kernel and
/// what the occupancy calculation consumes. `Eq + Hash` so it can key
/// the engine's memoized wave-size table ([`crate::engine::memo`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct LaunchConfig {
    /// Total thread blocks in the grid (`B` in Eq. 1).
    pub grid_blocks: u64,
    /// Threads per block.
    pub threads_per_block: u32,
    /// Registers per thread.
    pub regs_per_thread: u32,
    /// Static + dynamic shared memory per block, bytes.
    pub smem_per_block: u32,
}

impl LaunchConfig {
    pub fn new(grid_blocks: u64, threads_per_block: u32, regs_per_thread: u32, smem_per_block: u32) -> Self {
        LaunchConfig {
            grid_blocks,
            threads_per_block,
            regs_per_thread,
            smem_per_block,
        }
    }

    /// Warps per block (32 threads per warp, rounded up).
    pub fn warps_per_block(&self) -> u32 {
        self.threads_per_block.div_ceil(32)
    }
}

const WARP_SIZE: u32 = 32;
const REG_ALLOC_GRANULARITY: u32 = 256;
const SMEM_ALLOC_GRANULARITY: u32 = 256;

fn round_up(v: u32, granularity: u32) -> u32 {
    v.div_ceil(granularity) * granularity
}

/// Maximum thread blocks of this kernel resident on one SM.
pub fn blocks_per_sm(spec: &GpuSpec, cfg: &LaunchConfig) -> u32 {
    debug_assert!(cfg.threads_per_block >= 1);

    // 1. Hard block limit.
    let by_blocks = spec.max_blocks_per_sm;

    // 2. Thread residency.
    let by_threads = spec.max_threads_per_sm / cfg.threads_per_block.max(1);

    // 3. Register file. Registers are allocated per warp, rounded up.
    let regs_per_warp = round_up(cfg.regs_per_thread.max(1) * WARP_SIZE, REG_ALLOC_GRANULARITY);
    let regs_per_block = regs_per_warp * cfg.warps_per_block();
    let by_regs = if regs_per_block == 0 {
        by_blocks
    } else {
        spec.regs_per_sm / regs_per_block
    };

    // 4. Shared memory.
    let by_smem = if cfg.smem_per_block == 0 {
        by_blocks
    } else {
        spec.smem_per_sm_bytes / round_up(cfg.smem_per_block, SMEM_ALLOC_GRANULARITY)
    };

    by_blocks.min(by_threads).min(by_regs).min(by_smem).max(1)
    // `.max(1)`: a kernel that over-subscribes a single SM still runs one
    // block at a time (the driver would reject truly impossible launches;
    // our lowering never produces them).
}

/// Wave size `W_i`: resident blocks across the whole chip.
pub fn wave_size(spec: &GpuSpec, cfg: &LaunchConfig) -> u64 {
    blocks_per_sm(spec, cfg) as u64 * spec.sms as u64
}

/// Achieved occupancy as a fraction of the SM's thread residency limit.
/// The simulator uses this to derate memory-level parallelism for
/// low-occupancy kernels.
pub fn occupancy_fraction(spec: &GpuSpec, cfg: &LaunchConfig) -> f64 {
    let resident_threads = blocks_per_sm(spec, cfg) as f64 * cfg.threads_per_block as f64;
    (resident_threads / spec.max_threads_per_sm as f64).min(1.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::Device;

    fn cfg(threads: u32, regs: u32, smem: u32) -> LaunchConfig {
        LaunchConfig::new(1024, threads, regs, smem)
    }

    #[test]
    fn thread_limit_binds_for_light_kernels() {
        // 256-thread, low-register kernel on Volta: 2048/256 = 8 blocks/SM.
        let v100 = Device::V100.spec();
        assert_eq!(blocks_per_sm(v100, &cfg(256, 32, 0)), 8);
        // Same kernel on Turing (1024 threads/SM): 4 blocks/SM.
        let t4 = Device::T4.spec();
        assert_eq!(blocks_per_sm(t4, &cfg(256, 32, 0)), 4);
    }

    #[test]
    fn register_limit_binds_for_heavy_kernels() {
        // 256 threads × 128 regs = 32768 regs/block ⇒ 2 blocks/SM on 64k.
        let v100 = Device::V100.spec();
        assert_eq!(blocks_per_sm(v100, &cfg(256, 128, 0)), 2);
    }

    #[test]
    fn smem_limit_binds() {
        // 48 KiB smem per block on a 96 KiB SM ⇒ 2 blocks.
        let v100 = Device::V100.spec();
        assert_eq!(blocks_per_sm(v100, &cfg(128, 32, 48 * 1024)), 2);
        // On a 64 KiB-SM part ⇒ 1 block.
        let t4 = Device::T4.spec();
        assert_eq!(blocks_per_sm(t4, &cfg(128, 32, 48 * 1024)), 1);
    }

    #[test]
    fn block_limit_binds_for_tiny_blocks() {
        // 32-thread featherweight blocks: Volta caps at 32 blocks/SM.
        let v100 = Device::V100.spec();
        assert_eq!(blocks_per_sm(v100, &cfg(32, 16, 0)), 32);
    }

    #[test]
    fn wave_size_scales_with_sms() {
        let c = cfg(256, 32, 0);
        let w_v100 = wave_size(Device::V100.spec(), &c);
        let w_p4000 = wave_size(Device::P4000.spec(), &c);
        assert_eq!(w_v100, 8 * 80);
        assert_eq!(w_p4000, 8 * 14);
        assert!(w_v100 > w_p4000);
    }

    #[test]
    fn occupancy_fraction_bounds() {
        for d in crate::device::ALL_DEVICES {
            let f = occupancy_fraction(d.spec(), &cfg(256, 64, 16 * 1024));
            assert!((0.0..=1.0).contains(&f), "{d}: {f}");
        }
    }

    #[test]
    fn never_zero_blocks() {
        // Pathologically heavy kernel still gets one block.
        let t4 = Device::T4.spec();
        assert_eq!(blocks_per_sm(t4, &cfg(1024, 255, 64 * 1024)), 1);
    }
}
