//! Workload export: the predicted per-step compute + collective
//! schedule as COMM_OPS-style JSON records (op, bytes, participants),
//! consumable by an external network simulator.

use crate::util::json::Json;
use crate::Result;

use super::cluster::ClusterParams;
use super::collective::Collective;
use super::topology::Topology;

/// One collective in the exported schedule.
#[derive(Debug, Clone, PartialEq)]
pub struct CommOp {
    pub op: Collective,
    /// Payload size in bytes.
    pub bytes: f64,
    /// Global ranks taking part.
    pub participants: Vec<usize>,
}

impl CommOp {
    pub fn to_value(&self) -> Json {
        Json::obj(vec![
            ("op", Json::Str(self.op.wire_name().into())),
            ("bytes", Json::Num(self.bytes)),
            (
                "participants",
                Json::Arr(self.participants.iter().map(|r| Json::Num(*r as f64)).collect()),
            ),
        ])
    }

    pub fn from_value(v: &Json) -> Result<CommOp> {
        let name = v.req_str("op")?;
        let op = Collective::parse(name)
            .ok_or_else(|| anyhow::anyhow!("unknown collective op {name:?}"))?;
        let bytes = v
            .get("bytes")
            .and_then(Json::as_f64)
            .ok_or_else(|| anyhow::anyhow!("missing/invalid number field \"bytes\""))?;
        let participants = v
            .get("participants")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow::anyhow!("missing/invalid array field \"participants\""))?
            .iter()
            .map(|r| r.as_usize().ok_or_else(|| anyhow::anyhow!("non-integer rank in \"participants\"")))
            .collect::<Result<Vec<usize>>>()?;
        Ok(CommOp { op, bytes, participants })
    }
}

/// A predicted per-step workload: the compute span plus the gradient
/// collectives one data-parallel iteration issues.
#[derive(Debug, Clone, PartialEq)]
pub struct Workload {
    pub model: String,
    pub batch: usize,
    /// Origin (profiled) device name.
    pub origin: String,
    /// Destination (predicted) device name.
    pub dest: String,
    pub topology: String,
    pub world: usize,
    /// Per-replica compute time for one iteration, ms.
    pub compute_ms: f64,
    pub comm_ops: Vec<CommOp>,
}

impl Workload {
    pub fn to_value(&self) -> Json {
        Json::obj(vec![
            ("model", Json::Str(self.model.clone())),
            ("batch", Json::Num(self.batch as f64)),
            ("origin", Json::Str(self.origin.clone())),
            ("dest", Json::Str(self.dest.clone())),
            ("topology", Json::Str(self.topology.clone())),
            ("world", Json::Num(self.world as f64)),
            ("compute_ms", Json::Num(self.compute_ms)),
            ("comm_ops", Json::Arr(self.comm_ops.iter().map(CommOp::to_value).collect())),
        ])
    }

    pub fn from_value(v: &Json) -> Result<Workload> {
        Ok(Workload {
            model: v.req_str("model")?.to_string(),
            batch: v.req_usize("batch")?,
            origin: v.req_str("origin")?.to_string(),
            dest: v.req_str("dest")?.to_string(),
            topology: v.req_str("topology")?.to_string(),
            world: v.req_usize("world")?,
            compute_ms: v
                .get("compute_ms")
                .and_then(Json::as_f64)
                .ok_or_else(|| anyhow::anyhow!("missing/invalid number field \"compute_ms\""))?,
            comm_ops: v
                .get("comm_ops")
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow::anyhow!("missing/invalid array field \"comm_ops\""))?
                .iter()
                .map(CommOp::from_value)
                .collect::<Result<Vec<CommOp>>>()?,
        })
    }
}

/// The collectives one iteration issues for `grad_bytes` of gradients
/// on `topology` with `world` ranks, bucketed per
/// [`ClusterParams::bucket_bytes`].
///
/// Mirrors the cost model's schedule exactly: single-node buckets are
/// one flat ALLREDUCE over all ranks; multi-node buckets are per-node
/// REDUCESCATTER, an inter-node ALLREDUCE of the per-GPU shard over the
/// node leaders, and per-node ALLGATHER. Nodes with a single rank skip
/// the (no-op) intra stages.
pub fn comm_schedule(
    topology: Topology,
    world: usize,
    grad_bytes: f64,
    params: &ClusterParams,
) -> Vec<CommOp> {
    let mut ops = Vec::new();
    if world <= 1 || grad_bytes <= 0.0 {
        return ops;
    }
    let bucket = params.bucket_bytes;
    if bucket <= 0.0 || grad_bytes <= bucket {
        bucket_schedule(topology, world, grad_bytes, &mut ops);
        return ops;
    }
    let full = (grad_bytes / bucket).floor() as usize;
    for _ in 0..full {
        bucket_schedule(topology, world, bucket, &mut ops);
    }
    let rem = grad_bytes - full as f64 * bucket;
    if rem > 0.0 {
        bucket_schedule(topology, world, rem, &mut ops);
    }
    ops
}

fn bucket_schedule(topology: Topology, world: usize, bytes: f64, out: &mut Vec<CommOp>) {
    let spec = topology.spec();
    let g = (spec.gpus_per_node.max(1) as usize).min(world);
    if world <= spec.gpus_per_node.max(1) as usize {
        out.push(CommOp {
            op: Collective::AllReduce,
            bytes,
            participants: (0..world).collect(),
        });
        return;
    }
    let nodes = spec.nodes(world);
    let node_ranks =
        |node: usize| -> Vec<usize> { (node * g..((node + 1) * g).min(world)).collect() };
    for node in 0..nodes {
        let ranks = node_ranks(node);
        if ranks.len() > 1 {
            out.push(CommOp { op: Collective::ReduceScatter, bytes, participants: ranks });
        }
    }
    out.push(CommOp {
        op: Collective::AllReduce,
        bytes: bytes / g as f64,
        participants: (0..nodes).map(|node| node * g).collect(),
    });
    for node in 0..nodes {
        let ranks = node_ranks(node);
        if ranks.len() > 1 {
            out.push(CommOp { op: Collective::AllGather, bytes, participants: ranks });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json;

    #[test]
    fn single_node_bucket_is_one_flat_allreduce() {
        let params = ClusterParams { bucket_bytes: 0.0, ..Default::default() };
        let ops = comm_schedule(Topology::DGX, 4, 1e6, &params);
        assert_eq!(ops.len(), 1);
        assert_eq!(ops[0].op, Collective::AllReduce);
        assert_eq!(ops[0].bytes, 1e6);
        assert_eq!(ops[0].participants, vec![0, 1, 2, 3]);
    }

    #[test]
    fn world_one_has_no_collectives() {
        assert!(comm_schedule(Topology::DGX, 1, 1e9, &ClusterParams::default()).is_empty());
        assert!(comm_schedule(Topology::DGX, 8, 0.0, &ClusterParams::default()).is_empty());
    }

    #[test]
    fn hierarchical_bucket_has_rs_ar_ag_structure() {
        let params = ClusterParams { bucket_bytes: 0.0, ..Default::default() };
        // 16 ranks on dgx: 2 nodes of 8.
        let ops = comm_schedule(Topology::DGX, 16, 8e6, &params);
        assert_eq!(ops.len(), 2 + 1 + 2);
        assert_eq!(ops[0].op, Collective::ReduceScatter);
        assert_eq!(ops[0].participants, (0..8).collect::<Vec<_>>());
        assert_eq!(ops[1].participants, (8..16).collect::<Vec<_>>());
        let ar = &ops[2];
        assert_eq!(ar.op, Collective::AllReduce);
        assert_eq!(ar.bytes, 1e6); // 8e6 / 8 GPUs per node
        assert_eq!(ar.participants, vec![0, 8]); // node leaders
        assert_eq!(ops[3].op, Collective::AllGather);
        assert_eq!(ops[4].participants, (8..16).collect::<Vec<_>>());
        // Every participant is a valid rank.
        for op in &ops {
            assert!(op.participants.iter().all(|&r| r < 16));
        }
    }

    #[test]
    fn bucketing_repeats_the_schedule_per_bucket() {
        let params = ClusterParams { bucket_bytes: 1e6, ..Default::default() };
        let ops = comm_schedule(Topology::CLOUD, 4, 2.5e6, &params);
        // 2 full buckets + a 0.5e6 remainder, each one flat allreduce.
        assert_eq!(ops.len(), 3);
        assert_eq!(ops[0].bytes, 1e6);
        assert_eq!(ops[1].bytes, 1e6);
        assert!((ops[2].bytes - 0.5e6).abs() < 1e-6);
    }

    #[test]
    fn workload_round_trips_through_json() {
        let params = ClusterParams::default();
        let w = Workload {
            model: "resnet50".into(),
            batch: 32,
            origin: "rtx2070".into(),
            dest: "v100".into(),
            topology: "dgx".into(),
            world: 16,
            compute_ms: 123.456,
            comm_ops: comm_schedule(Topology::DGX, 16, 102.2e6, &params),
        };
        assert!(!w.comm_ops.is_empty());
        let text = w.to_value().dump();
        let parsed = Workload::from_value(&json::parse(&text).unwrap()).unwrap();
        assert_eq!(parsed, w);
    }

    #[test]
    fn comm_op_rejects_unknown_ops() {
        let v = json::parse(r#"{"op":"BROADCAST","bytes":1,"participants":[0]}"#).unwrap();
        assert!(CommOp::from_value(&v).is_err());
    }
}
