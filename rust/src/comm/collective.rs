//! Analytic cost functions for the standard collectives.
//!
//! All functions take the message size in bytes, the participant count
//! `world`, and a [`Link`] whose spec supplies the per-hop effective
//! bandwidth (bytes/s) and per-step launch latency (ms). They return
//! milliseconds, and all return `0.0` for `world <= 1` — a collective
//! over one rank is a no-op.
//!
//! The ring allreduce formula is the one every data-parallel
//! performance study uses (`2·(n−1)/n · bytes/BW + 2·(n−1)·latency`),
//! and its float-op order is kept identical to the legacy
//! `predict::distributed::ring_allreduce_ms` so seed links reproduce
//! the historical predictions bit-for-bit.

use super::Link;

/// The collective kinds the cost model (and the workload export) knows.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Collective {
    AllReduce,
    AllGather,
    ReduceScatter,
    AllToAll,
}

impl Collective {
    /// The COMM_OPS wire spelling (`ALLREDUCE`, `ALLGATHER`, …).
    pub fn wire_name(self) -> &'static str {
        match self {
            Collective::AllReduce => "ALLREDUCE",
            Collective::AllGather => "ALLGATHER",
            Collective::ReduceScatter => "REDUCESCATTER",
            Collective::AllToAll => "ALLTOALL",
        }
    }

    pub fn parse(name: &str) -> Option<Collective> {
        match name {
            "ALLREDUCE" => Some(Collective::AllReduce),
            "ALLGATHER" => Some(Collective::AllGather),
            "REDUCESCATTER" => Some(Collective::ReduceScatter),
            "ALLTOALL" => Some(Collective::AllToAll),
            _ => None,
        }
    }
}

impl std::fmt::Display for Collective {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.wire_name())
    }
}

/// Ring all-reduce: `2·(n−1)/n · bytes/BW + 2·(n−1)·latency`. The
/// float-op order matches the legacy constant-based implementation
/// exactly (pinned by a bit-identity test in `predict::distributed`).
pub fn ring_allreduce_ms(bytes: f64, world: usize, link: Link) -> f64 {
    let s = link.spec();
    ring_allreduce_ms_raw(bytes, world, s.bandwidth_bytes(), s.step_latency_ms)
}

/// [`ring_allreduce_ms`] over explicit per-hop parameters (bytes/s and
/// ms) — the compatibility path for `Interconnect::Custom` bandwidths
/// that never became registry links.
pub fn ring_allreduce_ms_raw(bytes: f64, world: usize, bandwidth_bytes: f64, step_latency_ms: f64) -> f64 {
    if world <= 1 {
        return 0.0;
    }
    let n = world as f64;
    let transfer = 2.0 * (n - 1.0) / n * bytes / bandwidth_bytes * 1e3;
    let latency = 2.0 * (n - 1.0) * step_latency_ms;
    transfer + latency
}

/// Binary-tree all-reduce (reduce + broadcast): `2·⌈log₂ n⌉` rounds,
/// each moving the full payload one level:
/// `2·⌈log₂ n⌉ · (bytes/BW + latency)`. Latency-bound small messages on
/// large worlds prefer this over the ring's `2(n−1)` steps.
pub fn tree_allreduce_ms(bytes: f64, world: usize, link: Link) -> f64 {
    if world <= 1 {
        return 0.0;
    }
    let s = link.spec();
    let rounds = 2.0 * (world as f64).log2().ceil();
    rounds * (bytes / s.bandwidth_bytes() * 1e3 + s.step_latency_ms)
}

/// The all-reduce the cluster model charges: the better of ring and
/// tree for the given message size and world (NCCL-style algorithm
/// selection).
pub fn allreduce_ms(bytes: f64, world: usize, link: Link) -> f64 {
    ring_allreduce_ms(bytes, world, link).min(tree_allreduce_ms(bytes, world, link))
}

/// Ring all-gather: each rank receives `(n−1)/n · bytes` over `n−1`
/// steps: `(n−1)/n · bytes/BW + (n−1)·latency`.
pub fn allgather_ms(bytes: f64, world: usize, link: Link) -> f64 {
    one_pass_ring_ms(bytes, world, link)
}

/// Ring reduce-scatter: the same wire volume as all-gather.
pub fn reduce_scatter_ms(bytes: f64, world: usize, link: Link) -> f64 {
    one_pass_ring_ms(bytes, world, link)
}

/// All-to-all: every rank exchanges `bytes/n` with each of its `n−1`
/// peers: `(n−1)/n · bytes/BW + (n−1)·latency` (pairwise-exchange
/// schedule).
pub fn alltoall_ms(bytes: f64, world: usize, link: Link) -> f64 {
    one_pass_ring_ms(bytes, world, link)
}

fn one_pass_ring_ms(bytes: f64, world: usize, link: Link) -> f64 {
    if world <= 1 {
        return 0.0;
    }
    let s = link.spec();
    let n = world as f64;
    (n - 1.0) / n * bytes / s.bandwidth_bytes() * 1e3 + (n - 1.0) * s.step_latency_ms
}

#[cfg(test)]
mod tests {
    use super::*;

    const SIZES: [f64; 6] = [0.0, 1e3, 1e5, 1e7, 1e9, 4e9];
    const WORLDS: [usize; 7] = [1, 2, 3, 4, 8, 64, 256];

    #[test]
    fn world_one_is_free_for_every_collective() {
        for l in [Link::PCIE3, Link::NVLINK, Link::ETHERNET_25G, Link::INFINIBAND] {
            for bytes in SIZES {
                assert_eq!(ring_allreduce_ms(bytes, 1, l), 0.0);
                assert_eq!(tree_allreduce_ms(bytes, 1, l), 0.0);
                assert_eq!(allreduce_ms(bytes, 1, l), 0.0);
                assert_eq!(allgather_ms(bytes, 1, l), 0.0);
                assert_eq!(reduce_scatter_ms(bytes, 1, l), 0.0);
                assert_eq!(alltoall_ms(bytes, 1, l), 0.0);
            }
        }
    }

    #[test]
    fn costs_are_monotone_in_bytes() {
        type CostFn = fn(f64, usize, Link) -> f64;
        let fns: [CostFn; 6] = [
            ring_allreduce_ms,
            tree_allreduce_ms,
            allreduce_ms,
            allgather_ms,
            reduce_scatter_ms,
            alltoall_ms,
        ];
        for f in fns {
            for world in WORLDS {
                for l in [Link::PCIE3, Link::ETHERNET_25G] {
                    let mut prev = -1.0;
                    for bytes in SIZES {
                        let ms = f(bytes, world, l);
                        assert!(ms.is_finite() && ms >= 0.0);
                        assert!(ms >= prev, "{ms} < {prev} at {bytes} bytes, world {world}");
                        prev = ms;
                    }
                }
            }
        }
    }

    #[test]
    fn ring_allreduce_approaches_bandwidth_asymptote() {
        // As bytes → ∞ the latency term vanishes:
        // time → 2(n−1)/n · bytes/BW.
        for world in [2usize, 4, 8, 64] {
            let n = world as f64;
            let bytes = 1e12;
            let bw = Link::PCIE3.spec().bandwidth_bytes();
            let asymptote = 2.0 * (n - 1.0) / n * bytes / bw * 1e3;
            let actual = ring_allreduce_ms(bytes, world, Link::PCIE3);
            assert!(
                (actual / asymptote - 1.0).abs() < 1e-6,
                "world {world}: {actual} vs asymptote {asymptote}"
            );
        }
    }

    #[test]
    fn tree_beats_ring_on_latency_bound_messages() {
        // 1 KiB over 256 ranks: the ring pays 510 latency steps, the
        // tree pays 16 rounds.
        let bytes = 1024.0;
        let tree = tree_allreduce_ms(bytes, 256, Link::ETHERNET_25G);
        let ring = ring_allreduce_ms(bytes, 256, Link::ETHERNET_25G);
        assert!(tree < ring, "tree {tree} vs ring {ring}");
        assert_eq!(allreduce_ms(bytes, 256, Link::ETHERNET_25G), tree);
        // 1 GiB over 4 ranks: bandwidth-bound, the ring's 2(n−1)/n
        // factor wins over the tree's 2·log₂ n full-payload rounds.
        let big = 1e9;
        assert!(ring_allreduce_ms(big, 4, Link::PCIE3) < tree_allreduce_ms(big, 4, Link::PCIE3));
    }

    #[test]
    fn faster_links_are_faster() {
        for world in [2usize, 8, 64] {
            let bytes = 1e8;
            assert!(
                ring_allreduce_ms(bytes, world, Link::NVLINK)
                    < ring_allreduce_ms(bytes, world, Link::PCIE3)
            );
            assert!(
                ring_allreduce_ms(bytes, world, Link::PCIE3)
                    < ring_allreduce_ms(bytes, world, Link::ETHERNET_25G)
            );
        }
    }

    #[test]
    fn collective_wire_names_round_trip() {
        for c in [
            Collective::AllReduce,
            Collective::AllGather,
            Collective::ReduceScatter,
            Collective::AllToAll,
        ] {
            assert_eq!(Collective::parse(c.wire_name()), Some(c));
        }
        assert_eq!(Collective::parse("BROADCAST"), None);
    }
}
