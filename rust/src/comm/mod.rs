//! Topology-aware communication cost modeling — the cluster half of a
//! distributed-training prediction.
//!
//! Habitat predicts the *compute* side of an iteration (one GPU, one
//! step). Scaling that answer to a cluster decision — "how many of
//! which GPU, on which interconnect" — needs the *communication* side:
//! what the gradient collectives cost on a concrete topology, and how
//! much of that cost hides behind the backward pass. This module
//! supplies it:
//!
//! * [`Link`] — an interned interconnect description (effective bus
//!   bandwidth + per-step launch latency), kept in a process-wide
//!   registry exactly like [`crate::device::registry`]: the paper-set
//!   links (PCIe 3/4, NVLink, 25G Ethernet) are **seed entries** with
//!   the historical constants, and new links can be [`register_link`]ed
//!   at runtime (from library code or over the wire).
//! * [`collective`] — analytic cost functions for the standard
//!   collectives (ring and tree ALLREDUCE, ALLGATHER, REDUCESCATTER,
//!   ALLTOALL), parameterized by message size, world size, and the
//!   link's per-hop bandwidth/latency.
//! * [`topology`] — a [`Topology`] (GPUs per node, intra-node link,
//!   inter-node link; also registry-interned) plus the hierarchical
//!   allreduce composition over it.
//! * [`cluster`] — the per-step composition: Habitat compute time +
//!   bucketed allreduce overlapped with backward
//!   (`exposed = max(0, comm − overlappable backward span)`).
//! * [`export`] — the predicted per-step schedule as COMM_OPS-style
//!   records (op, bytes, participants) so predictions can drive an
//!   external network simulator.

use std::sync::{OnceLock, RwLock};

pub use crate::device::RegisterError;

pub mod cluster;
pub mod collective;
pub mod export;
pub mod topology;

pub use cluster::{trace_comm, ClusterParams, ClusterPrediction, TraceComm};
pub use collective::{
    allgather_ms, alltoall_ms, reduce_scatter_ms, ring_allreduce_ms, tree_allreduce_ms, Collective,
};
pub use export::{comm_schedule, CommOp, Workload};
pub use topology::{NewTopology, Topology, TopologySpec};

/// An interned interconnect: an index into the process-wide link
/// registry (seed links at fixed indices, runtime registrations after).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct Link(pub(crate) u32);

/// One link's cost-model parameters.
#[derive(Debug, Clone, PartialEq)]
pub struct LinkSpec {
    pub link: Link,
    /// Short unique name (case-insensitive lookups).
    pub name: &'static str,
    /// Effective all-reduce bus bandwidth, GB/s.
    pub bandwidth_gbps: f64,
    /// Per-message launch latency (one ring step / tree round), ms.
    pub step_latency_ms: f64,
}

impl LinkSpec {
    /// Effective bus bandwidth in bytes/s (the unit the cost formulas
    /// use). Computed as `gbps * 1e9` — the exact expression the old
    /// `Interconnect::bandwidth_bytes` constants used, so seed links
    /// reproduce the legacy model bit-for-bit.
    pub fn bandwidth_bytes(&self) -> f64 {
        self.bandwidth_gbps * 1e9
    }
}

/// The paper-set links (+ one InfiniBand-class inter-node seed), always
/// present at indices `0..5`. Bandwidth/latency values for the first
/// four are the exact constants the deprecated
/// [`crate::predict::distributed::Interconnect`] enum hard-coded.
const BUILTIN_LINKS: [LinkSpec; 5] = [
    LinkSpec { link: Link(0), name: "pcie3", bandwidth_gbps: 12.0, step_latency_ms: 0.01 },
    LinkSpec { link: Link(1), name: "pcie4", bandwidth_gbps: 24.0, step_latency_ms: 0.01 },
    LinkSpec { link: Link(2), name: "nvlink", bandwidth_gbps: 130.0, step_latency_ms: 0.01 },
    LinkSpec { link: Link(3), name: "eth25g", bandwidth_gbps: 2.9, step_latency_ms: 0.03 },
    LinkSpec { link: Link(4), name: "ib-hdr", bandwidth_gbps: 25.0, step_latency_ms: 0.005 },
];

/// Extra accepted names for [`find_link`].
const LINK_ALIASES: [(&str, Link); 2] = [
    ("ethernet25g", Link::ETHERNET_25G),
    ("infiniband", Link::INFINIBAND),
];

/// Hard cap on registry size (each registration leaks one spec).
pub const MAX_LINKS: usize = 256;

impl Link {
    /// PCIe 3.0 x16 (~12 GB/s effective).
    pub const PCIE3: Link = Link(0);
    /// PCIe 4.0 x16 (~24 GB/s effective).
    pub const PCIE4: Link = Link(1);
    /// NVLink 2.0 (V100-class, ~130 GB/s effective per GPU).
    pub const NVLINK: Link = Link(2);
    /// 25 Gb/s Ethernet between nodes (~2.9 GB/s effective).
    pub const ETHERNET_25G: Link = Link(3);
    /// HDR InfiniBand between nodes (~25 GB/s effective).
    pub const INFINIBAND: Link = Link(4);

    /// Registry index of this link.
    pub fn index(self) -> usize {
        self.0 as usize
    }

    /// The interned spec (panics for an id the registry never minted).
    pub fn spec(self) -> &'static LinkSpec {
        try_link_spec(self)
            .unwrap_or_else(|| panic!("link id {} is not in the registry", self.index()))
    }

    pub fn name(self) -> &'static str {
        self.spec().name
    }

    /// Case-insensitive name (or alias) lookup.
    pub fn parse(name: &str) -> Option<Link> {
        find_link(name)
    }
}

impl std::fmt::Display for Link {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.name())
    }
}

/// Runtime-registered link specs (beyond the seeds), in id order.
fn extra_links() -> &'static RwLock<Vec<&'static LinkSpec>> {
    static EXTRA: OnceLock<RwLock<Vec<&'static LinkSpec>>> = OnceLock::new();
    EXTRA.get_or_init(|| RwLock::new(Vec::new()))
}

/// Number of links currently registered (seeds included).
pub fn link_count() -> usize {
    BUILTIN_LINKS.len() + extra_links().read().unwrap().len()
}

/// Every registered link, in id order (seeds first).
pub fn all_links() -> Vec<Link> {
    (0..link_count() as u32).map(Link).collect()
}

/// Every registered link name, in id order (for error messages).
pub fn link_names() -> Vec<&'static str> {
    let mut names: Vec<&'static str> = BUILTIN_LINKS.iter().map(|s| s.name).collect();
    names.extend(extra_links().read().unwrap().iter().map(|s| s.name));
    names
}

/// Spec lookup; `None` for an id this registry never minted.
pub fn try_link_spec(l: Link) -> Option<&'static LinkSpec> {
    let i = l.index();
    if i < BUILTIN_LINKS.len() {
        Some(&BUILTIN_LINKS[i])
    } else {
        extra_links().read().unwrap().get(i - BUILTIN_LINKS.len()).copied()
    }
}

/// Case-insensitive name (or alias) lookup.
pub fn find_link(name: &str) -> Option<Link> {
    let lower = name.to_ascii_lowercase();
    for s in &BUILTIN_LINKS {
        if s.name == lower {
            return Some(s.link);
        }
    }
    for (alias, l) in LINK_ALIASES {
        if alias == lower {
            return Some(l);
        }
    }
    let extras = extra_links().read().unwrap();
    for (i, s) in extras.iter().enumerate() {
        if s.name.to_ascii_lowercase() == lower {
            return Some(Link((BUILTIN_LINKS.len() + i) as u32));
        }
    }
    None
}

/// A new link description, as supplied by `register_link` (library or
/// wire — inline link objects in cluster requests route here).
#[derive(Debug, Clone)]
pub struct NewLink {
    /// Short unique name; 1–64 chars of `[A-Za-z0-9._-]`,
    /// compared case-insensitively.
    pub name: String,
    /// Effective all-reduce bus bandwidth, GB/s.
    pub bandwidth_gbps: f64,
    /// Per-message launch latency, ms.
    pub step_latency_ms: f64,
}

fn validate_link(d: &NewLink) -> Result<(), RegisterError> {
    let bad = |m: String| Err(RegisterError::Invalid(m));
    if d.name.is_empty() || d.name.len() > 64 {
        return bad("link name must be 1..=64 characters".into());
    }
    if !d.name.chars().all(|c| c.is_ascii_alphanumeric() || matches!(c, '.' | '_' | '-')) {
        return bad(format!("link name {:?} has characters outside [A-Za-z0-9._-]", d.name));
    }
    if !(d.bandwidth_gbps.is_finite() && d.bandwidth_gbps > 0.0) {
        return bad("bandwidth_gbps must be a positive number".into());
    }
    if !(d.step_latency_ms.is_finite() && d.step_latency_ms >= 0.0) {
        return bad("step_latency_ms must be a non-negative number".into());
    }
    Ok(())
}

fn same_link(a: &LinkSpec, b: &NewLink) -> bool {
    a.bandwidth_gbps == b.bandwidth_gbps && a.step_latency_ms == b.step_latency_ms
}

/// Register a new link, returning its interned handle.
///
/// Idempotent: re-registering an identical description returns the
/// existing handle. A name collision with a *different* spec —
/// including the seed names and aliases — is a
/// [`RegisterError::Conflict`].
pub fn register_link(desc: &NewLink) -> Result<Link, RegisterError> {
    validate_link(desc)?;
    let lower = desc.name.to_ascii_lowercase();

    for s in &BUILTIN_LINKS {
        if s.name == lower {
            return if same_link(s, desc) {
                Ok(s.link)
            } else {
                Err(RegisterError::Conflict(format!(
                    "link name {:?} is taken by a built-in link with a different spec",
                    desc.name
                )))
            };
        }
    }
    if LINK_ALIASES.iter().any(|(alias, _)| *alias == lower) {
        return Err(RegisterError::Conflict(format!(
            "link name {:?} is a reserved alias",
            desc.name
        )));
    }

    // Hold the write lock across the lookup so two racing registrations
    // of the same name can't both insert.
    let mut extras = extra_links().write().unwrap();
    for (i, s) in extras.iter().enumerate() {
        if s.name.to_ascii_lowercase() == lower {
            return if same_link(s, desc) {
                Ok(Link((BUILTIN_LINKS.len() + i) as u32))
            } else {
                Err(RegisterError::Conflict(format!(
                    "link name {:?} is already registered with a different spec",
                    desc.name
                )))
            };
        }
    }

    if BUILTIN_LINKS.len() + extras.len() >= MAX_LINKS {
        return Err(RegisterError::Invalid(format!(
            "link registry is full ({MAX_LINKS} links)"
        )));
    }
    let id = Link((BUILTIN_LINKS.len() + extras.len()) as u32);
    let spec = LinkSpec {
        link: id,
        name: Box::leak(desc.name.clone().into_boxed_str()),
        bandwidth_gbps: desc.bandwidth_gbps,
        step_latency_ms: desc.step_latency_ms,
    };
    extras.push(Box::leak(Box::new(spec)));
    Ok(id)
}

#[cfg(test)]
mod tests {
    use super::*;

    // NOTE: the registry is process-global and `cargo test` runs tests
    // concurrently in one process — tests use unique `sim-*` names,
    // assert "contains"-style, and never register names other tests
    // expect to be unknown (e.g. "no-such-link").

    #[test]
    fn seed_links_carry_the_legacy_constants() {
        assert_eq!(Link::PCIE3.spec().bandwidth_bytes().to_bits(), (12.0f64 * 1e9).to_bits());
        assert_eq!(Link::PCIE4.spec().bandwidth_bytes().to_bits(), (24.0f64 * 1e9).to_bits());
        assert_eq!(Link::NVLINK.spec().bandwidth_bytes().to_bits(), (130.0f64 * 1e9).to_bits());
        assert_eq!(
            Link::ETHERNET_25G.spec().bandwidth_bytes().to_bits(),
            (2.9f64 * 1e9).to_bits()
        );
        assert_eq!(Link::ETHERNET_25G.spec().step_latency_ms, 0.03);
        for l in [Link::PCIE3, Link::PCIE4, Link::NVLINK] {
            assert_eq!(l.spec().step_latency_ms, 0.01);
        }
    }

    #[test]
    fn find_is_case_insensitive_and_knows_aliases() {
        assert_eq!(find_link("NVLink"), Some(Link::NVLINK));
        assert_eq!(find_link("ethernet25g"), Some(Link::ETHERNET_25G));
        assert_eq!(find_link("infiniband"), Some(Link::INFINIBAND));
        assert_eq!(find_link("no-such-link"), None);
    }

    #[test]
    fn register_then_find_and_enumerate() {
        let l = register_link(&NewLink {
            name: "sim-roce100".into(),
            bandwidth_gbps: 11.0,
            step_latency_ms: 0.015,
        })
        .unwrap();
        assert_eq!(Link::parse("SIM-ROCE100"), Some(l));
        assert_eq!(l.spec().bandwidth_gbps, 11.0);
        assert!(all_links().contains(&l));
        assert!(link_names().contains(&"sim-roce100"));
        assert_eq!(format!("{l}"), "sim-roce100");
    }

    #[test]
    fn reregistration_is_idempotent_and_conflicts_are_refused() {
        let desc = NewLink { name: "sim-idem-link".into(), bandwidth_gbps: 7.0, step_latency_ms: 0.02 };
        let a = register_link(&desc).unwrap();
        let b = register_link(&desc).unwrap();
        assert_eq!(a, b);
        let clash = NewLink { bandwidth_gbps: 8.0, ..desc };
        assert!(matches!(register_link(&clash), Err(RegisterError::Conflict(_))));
        let builtin = NewLink { name: "nvlink".into(), bandwidth_gbps: 1.0, step_latency_ms: 0.0 };
        assert!(matches!(register_link(&builtin), Err(RegisterError::Conflict(_))));
        let alias = NewLink { name: "infiniband".into(), bandwidth_gbps: 1.0, step_latency_ms: 0.0 };
        assert!(matches!(register_link(&alias), Err(RegisterError::Conflict(_))));
    }

    #[test]
    fn validation_rejects_nonsense() {
        let bad = |d: NewLink| matches!(register_link(&d), Err(RegisterError::Invalid(_)));
        assert!(bad(NewLink { name: "".into(), bandwidth_gbps: 1.0, step_latency_ms: 0.0 }));
        assert!(bad(NewLink { name: "bad name".into(), bandwidth_gbps: 1.0, step_latency_ms: 0.0 }));
        assert!(bad(NewLink { name: "sim-neg-bw".into(), bandwidth_gbps: -1.0, step_latency_ms: 0.0 }));
        assert!(bad(NewLink {
            name: "sim-nan-lat".into(),
            bandwidth_gbps: 1.0,
            step_latency_ms: f64::NAN,
        }));
    }
}
