//! Cluster topology descriptions and the hierarchical allreduce over
//! them.
//!
//! A [`Topology`] fixes the *shape* of a cluster — how many GPUs share
//! a node, what link connects GPUs inside a node, and what link
//! connects nodes — without fixing the world size; the same topology
//! handle serves a whole `{1,2,4,…,256}`-rank sweep. Topologies are
//! registry-interned exactly like [`super::Link`] and
//! [`crate::device::registry`]: two seeds ("dgx", "cloud") are always
//! present, and new shapes can be registered at runtime.

use std::sync::{OnceLock, RwLock};

use super::collective;
use super::{find_link, try_link_spec, Link, RegisterError};

/// An interned topology: an index into the process-wide registry.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct Topology(pub(crate) u32);

/// One topology's shape parameters.
#[derive(Debug, Clone, PartialEq)]
pub struct TopologySpec {
    pub topology: Topology,
    /// Short unique name (case-insensitive lookups).
    pub name: &'static str,
    /// GPUs per node; worlds larger than this span nodes.
    pub gpus_per_node: u32,
    /// Link between GPUs inside one node.
    pub intra: Link,
    /// Link between nodes.
    pub inter: Link,
}

/// The seed topologies, always present at indices `0..2`: an NVLink +
/// InfiniBand DGX-style pod and a PCIe + 25G-Ethernet cloud instance.
const BUILTIN_TOPOLOGIES: [TopologySpec; 2] = [
    TopologySpec {
        topology: Topology(0),
        name: "dgx",
        gpus_per_node: 8,
        intra: Link::NVLINK,
        inter: Link::INFINIBAND,
    },
    TopologySpec {
        topology: Topology(1),
        name: "cloud",
        gpus_per_node: 4,
        intra: Link::PCIE3,
        inter: Link::ETHERNET_25G,
    },
];

/// Hard cap on registry size (each registration leaks one spec).
pub const MAX_TOPOLOGIES: usize = 256;

impl Topology {
    /// 8×NVLink GPUs per node, HDR InfiniBand between nodes.
    pub const DGX: Topology = Topology(0);
    /// 4×PCIe-3 GPUs per node, 25G Ethernet between nodes.
    pub const CLOUD: Topology = Topology(1);

    /// Registry index of this topology.
    pub fn index(self) -> usize {
        self.0 as usize
    }

    /// The interned spec (panics for an id the registry never minted).
    pub fn spec(self) -> &'static TopologySpec {
        try_topology_spec(self)
            .unwrap_or_else(|| panic!("topology id {} is not in the registry", self.index()))
    }

    pub fn name(self) -> &'static str {
        self.spec().name
    }

    /// Case-insensitive name lookup.
    pub fn parse(name: &str) -> Option<Topology> {
        find_topology(name)
    }
}

impl std::fmt::Display for Topology {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.name())
    }
}

impl TopologySpec {
    /// Nodes a `world`-rank job occupies (the last node may be partially
    /// filled).
    pub fn nodes(&self, world: usize) -> usize {
        world.div_ceil(self.gpus_per_node.max(1) as usize)
    }

    /// One all-reduce of `bytes` over `world` ranks on this topology,
    /// in ms.
    ///
    /// Flat (single-node) worlds pay the better of ring/tree over the
    /// intra-node link. Multi-node worlds pay the standard hierarchical
    /// schedule: intra-node reduce-scatter, inter-node all-reduce over
    /// one shard per node, intra-node all-gather — the intra stages move
    /// the full payload inside each node while the inter stage moves
    /// only `bytes / gpus_per_node` between node leaders.
    pub fn allreduce_ms(&self, bytes: f64, world: usize) -> f64 {
        if world <= 1 {
            return 0.0;
        }
        let g = self.gpus_per_node.max(1) as usize;
        if world <= g {
            return collective::allreduce_ms(bytes, world, self.intra);
        }
        let nodes = self.nodes(world);
        collective::reduce_scatter_ms(bytes, g, self.intra)
            + collective::allreduce_ms(bytes / g as f64, nodes, self.inter)
            + collective::allgather_ms(bytes, g, self.intra)
    }
}

/// Runtime-registered topology specs (beyond the seeds), in id order.
fn extra_topologies() -> &'static RwLock<Vec<&'static TopologySpec>> {
    static EXTRA: OnceLock<RwLock<Vec<&'static TopologySpec>>> = OnceLock::new();
    EXTRA.get_or_init(|| RwLock::new(Vec::new()))
}

/// Number of topologies currently registered (seeds included).
pub fn topology_count() -> usize {
    BUILTIN_TOPOLOGIES.len() + extra_topologies().read().unwrap().len()
}

/// Every registered topology, in id order (seeds first).
pub fn all_topologies() -> Vec<Topology> {
    (0..topology_count() as u32).map(Topology).collect()
}

/// Every registered topology name, in id order (for error messages).
pub fn topology_names() -> Vec<&'static str> {
    let mut names: Vec<&'static str> = BUILTIN_TOPOLOGIES.iter().map(|s| s.name).collect();
    names.extend(extra_topologies().read().unwrap().iter().map(|s| s.name));
    names
}

/// Spec lookup; `None` for an id this registry never minted.
pub fn try_topology_spec(t: Topology) -> Option<&'static TopologySpec> {
    let i = t.index();
    if i < BUILTIN_TOPOLOGIES.len() {
        Some(&BUILTIN_TOPOLOGIES[i])
    } else {
        extra_topologies().read().unwrap().get(i - BUILTIN_TOPOLOGIES.len()).copied()
    }
}

/// Case-insensitive name lookup.
pub fn find_topology(name: &str) -> Option<Topology> {
    let lower = name.to_ascii_lowercase();
    for s in &BUILTIN_TOPOLOGIES {
        if s.name == lower {
            return Some(s.topology);
        }
    }
    let extras = extra_topologies().read().unwrap();
    for (i, s) in extras.iter().enumerate() {
        if s.name.to_ascii_lowercase() == lower {
            return Some(Topology((BUILTIN_TOPOLOGIES.len() + i) as u32));
        }
    }
    None
}

/// A new topology description, as supplied by `register_topology`
/// (library or wire — inline topology objects in cluster requests
/// route here).
#[derive(Debug, Clone)]
pub struct NewTopology {
    /// Short unique name; 1–64 chars of `[A-Za-z0-9._-]`,
    /// compared case-insensitively.
    pub name: String,
    pub gpus_per_node: u32,
    pub intra: Link,
    pub inter: Link,
}

fn validate_topology(d: &NewTopology) -> Result<(), RegisterError> {
    let bad = |m: String| Err(RegisterError::Invalid(m));
    if d.name.is_empty() || d.name.len() > 64 {
        return bad("topology name must be 1..=64 characters".into());
    }
    if !d.name.chars().all(|c| c.is_ascii_alphanumeric() || matches!(c, '.' | '_' | '-')) {
        return bad(format!("topology name {:?} has characters outside [A-Za-z0-9._-]", d.name));
    }
    if d.gpus_per_node == 0 || d.gpus_per_node > 4096 {
        return bad("gpus_per_node must be in 1..=4096".into());
    }
    for (role, l) in [("intra", d.intra), ("inter", d.inter)] {
        if try_link_spec(l).is_none() {
            return bad(format!("{role} link id {} is not in the link registry", l.index()));
        }
    }
    Ok(())
}

fn same_topology(a: &TopologySpec, b: &NewTopology) -> bool {
    a.gpus_per_node == b.gpus_per_node && a.intra == b.intra && a.inter == b.inter
}

/// Register a new topology, returning its interned handle.
///
/// Idempotent: re-registering an identical description returns the
/// existing handle. A name collision with a *different* spec —
/// including the seed names — is a [`RegisterError::Conflict`].
pub fn register_topology(desc: &NewTopology) -> Result<Topology, RegisterError> {
    validate_topology(desc)?;
    let lower = desc.name.to_ascii_lowercase();

    for s in &BUILTIN_TOPOLOGIES {
        if s.name == lower {
            return if same_topology(s, desc) {
                Ok(s.topology)
            } else {
                Err(RegisterError::Conflict(format!(
                    "topology name {:?} is taken by a built-in topology with a different spec",
                    desc.name
                )))
            };
        }
    }

    // Hold the write lock across the lookup so two racing registrations
    // of the same name can't both insert.
    let mut extras = extra_topologies().write().unwrap();
    for (i, s) in extras.iter().enumerate() {
        if s.name.to_ascii_lowercase() == lower {
            return if same_topology(s, desc) {
                Ok(Topology((BUILTIN_TOPOLOGIES.len() + i) as u32))
            } else {
                Err(RegisterError::Conflict(format!(
                    "topology name {:?} is already registered with a different spec",
                    desc.name
                )))
            };
        }
    }

    if BUILTIN_TOPOLOGIES.len() + extras.len() >= MAX_TOPOLOGIES {
        return Err(RegisterError::Invalid(format!(
            "topology registry is full ({MAX_TOPOLOGIES} topologies)"
        )));
    }
    let id = Topology((BUILTIN_TOPOLOGIES.len() + extras.len()) as u32);
    let spec = TopologySpec {
        topology: id,
        name: Box::leak(desc.name.clone().into_boxed_str()),
        gpus_per_node: desc.gpus_per_node,
        intra: desc.intra,
        inter: desc.inter,
    };
    extras.push(Box::leak(Box::new(spec)));
    Ok(id)
}

#[cfg(test)]
mod tests {
    use super::*;

    // Same process-global-registry conventions as the link tests:
    // unique `sim-*` names, contains-style asserts, and never register
    // names other tests expect unknown (e.g. "no-such-topology").

    #[test]
    fn seed_topologies_are_findable() {
        assert_eq!(find_topology("DGX"), Some(Topology::DGX));
        assert_eq!(find_topology("cloud"), Some(Topology::CLOUD));
        assert_eq!(find_topology("no-such-topology"), None);
        assert_eq!(Topology::DGX.spec().gpus_per_node, 8);
        assert_eq!(Topology::DGX.spec().intra, Link::NVLINK);
        assert_eq!(Topology::CLOUD.spec().inter, Link::ETHERNET_25G);
        assert_eq!(format!("{}", Topology::CLOUD), "cloud");
    }

    #[test]
    fn node_count_rounds_up() {
        let dgx = Topology::DGX.spec();
        assert_eq!(dgx.nodes(1), 1);
        assert_eq!(dgx.nodes(8), 1);
        assert_eq!(dgx.nodes(9), 2);
        assert_eq!(dgx.nodes(256), 32);
    }

    #[test]
    fn single_node_worlds_use_the_intra_link_only() {
        let dgx = Topology::DGX.spec();
        let bytes = 1e8;
        for world in [2usize, 4, 8] {
            assert_eq!(
                dgx.allreduce_ms(bytes, world).to_bits(),
                collective::allreduce_ms(bytes, world, Link::NVLINK).to_bits()
            );
        }
        assert_eq!(dgx.allreduce_ms(bytes, 1), 0.0);
    }

    #[test]
    fn hierarchical_allreduce_matches_its_stage_sum() {
        let dgx = Topology::DGX.spec();
        let bytes = 4.08e8;
        let world = 32;
        let expect = collective::reduce_scatter_ms(bytes, 8, Link::NVLINK)
            + collective::allreduce_ms(bytes / 8.0, 4, Link::INFINIBAND)
            + collective::allgather_ms(bytes, 8, Link::NVLINK);
        assert_eq!(dgx.allreduce_ms(bytes, world).to_bits(), expect.to_bits());
    }

    #[test]
    fn allreduce_is_monotone_in_bytes_and_never_negative() {
        for t in [Topology::DGX, Topology::CLOUD] {
            let spec = t.spec();
            for world in [1usize, 2, 8, 9, 64, 256] {
                let mut prev = -1.0;
                for bytes in [0.0, 1e3, 1e6, 1e9] {
                    let ms = spec.allreduce_ms(bytes, world);
                    assert!(ms.is_finite() && ms >= 0.0);
                    assert!(ms >= prev);
                    prev = ms;
                }
            }
        }
    }

    #[test]
    fn dgx_is_faster_than_cloud() {
        for world in [2usize, 8, 64, 256] {
            let bytes = 1e8;
            assert!(
                Topology::DGX.spec().allreduce_ms(bytes, world)
                    < Topology::CLOUD.spec().allreduce_ms(bytes, world)
            );
        }
    }

    #[test]
    fn register_find_idempotence_and_conflicts() {
        let desc = NewTopology {
            name: "sim-pod16".into(),
            gpus_per_node: 16,
            intra: Link::NVLINK,
            inter: Link::INFINIBAND,
        };
        let a = register_topology(&desc).unwrap();
        let b = register_topology(&desc).unwrap();
        assert_eq!(a, b);
        assert_eq!(Topology::parse("SIM-POD16"), Some(a));
        assert!(all_topologies().contains(&a));
        assert!(topology_names().contains(&"sim-pod16"));
        let clash = NewTopology { gpus_per_node: 8, ..desc.clone() };
        assert!(matches!(register_topology(&clash), Err(RegisterError::Conflict(_))));
        let builtin = NewTopology { name: "dgx".into(), ..desc };
        assert!(matches!(register_topology(&builtin), Err(RegisterError::Conflict(_))));
    }

    #[test]
    fn validation_rejects_nonsense() {
        let bad = |d: NewTopology| matches!(register_topology(&d), Err(RegisterError::Invalid(_)));
        assert!(bad(NewTopology {
            name: "".into(),
            gpus_per_node: 8,
            intra: Link::NVLINK,
            inter: Link::INFINIBAND,
        }));
        assert!(bad(NewTopology {
            name: "sim-zero-gpus".into(),
            gpus_per_node: 0,
            intra: Link::NVLINK,
            inter: Link::INFINIBAND,
        }));
        assert!(bad(NewTopology {
            name: "sim-bad-link".into(),
            gpus_per_node: 8,
            intra: Link(9999),
            inter: Link::INFINIBAND,
        }));
    }
}
