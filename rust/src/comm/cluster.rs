//! The per-step cluster composition: Habitat compute time + bucketed
//! allreduce overlapped with backward.
//!
//! This is the topology-aware successor to
//! [`crate::predict::distributed`]'s flat composition. The overlap
//! arithmetic is identical (`exposed = max(0, comm − overlap ·
//! bwd_fraction · compute)`), but the communication term is the
//! hierarchical [`TopologySpec::allreduce_ms`] applied per DDP gradient
//! bucket instead of one flat ring over a single link. At `world == 1`
//! communication is zero and `iter_ms` reproduces the single-GPU
//! compute prediction bit-for-bit.

use crate::tracker::Trace;

use super::topology::Topology;

/// Tunables of the data-parallel composition (the topology itself is a
/// separate argument, so one `ClusterParams` serves a whole sweep).
#[derive(Debug, Clone, Copy)]
pub struct ClusterParams {
    /// Fraction of the backward pass that gradient communication can
    /// overlap with (bucketed all-reduce à la PyTorch DDP). 0 = fully
    /// exposed, 1 = fully overlappable.
    pub overlap: f64,
    /// DDP gradient-bucket size in bytes; the allreduce is charged per
    /// bucket. `<= 0` disables bucketing (one flat allreduce).
    pub bucket_bytes: f64,
}

impl Default for ClusterParams {
    fn default() -> Self {
        // PyTorch DDP's default bucket_cap_mb = 25 MiB; overlap matches
        // the legacy DataParallelConfig default.
        ClusterParams { overlap: 0.7, bucket_bytes: 25.0 * 1024.0 * 1024.0 }
    }
}

/// The destination-independent communication inputs derived from the
/// origin trace, hoisted so a whole topology × world sweep pays them
/// once.
#[derive(Debug, Clone, Copy)]
pub struct TraceComm {
    /// FP32 gradient volume: 4 bytes per trainable parameter.
    pub grad_bytes: f64,
    /// Backward share of the iteration (from the origin trace's fwd/bwd
    /// split, assumed stable across devices).
    pub bwd_fraction: f64,
}

/// Derive the communication inputs from an origin trace. Exact same
/// arithmetic the legacy `predict::distributed` path used (and now
/// delegates to).
pub fn trace_comm(trace: &Trace) -> TraceComm {
    let grad_bytes: f64 = trace
        .ops
        .iter()
        .map(|o| o.op.kind.parameter_count() as f64 * 4.0)
        .sum();
    let (fwd, bwd): (f64, f64) = trace
        .ops
        .iter()
        .fold((0.0, 0.0), |(f, b), o| (f + o.fwd_ms(), b + o.bwd_ms()));
    let bwd_fraction = if fwd + bwd > 0.0 { bwd / (fwd + bwd) } else { 0.5 };
    TraceComm {
        grad_bytes,
        bwd_fraction,
    }
}

/// One (topology, world) cell of a cluster prediction.
#[derive(Debug, Clone, Copy)]
pub struct ClusterPrediction {
    /// Number of replicas (GPUs).
    pub world: usize,
    /// Per-replica compute time (Habitat's single-GPU prediction), ms.
    pub compute_ms: f64,
    /// Total collective time (bucketed hierarchical allreduce), ms.
    pub comm_ms: f64,
    /// Collective time not hidden behind the backward pass, ms.
    pub exposed_ms: f64,
    /// Predicted distributed iteration time, ms.
    pub iter_ms: f64,
    /// Global throughput, samples/s (world × per-replica batch).
    pub throughput: f64,
    /// Scaling efficiency vs `world ×` the single-GPU throughput.
    pub efficiency: f64,
}

/// Total allreduce time for `grad_bytes` charged per DDP bucket.
pub fn bucketed_allreduce_ms(
    topology: Topology,
    world: usize,
    grad_bytes: f64,
    bucket_bytes: f64,
) -> f64 {
    if world <= 1 || grad_bytes <= 0.0 {
        return 0.0;
    }
    let spec = topology.spec();
    if bucket_bytes <= 0.0 || grad_bytes <= bucket_bytes {
        return spec.allreduce_ms(grad_bytes, world);
    }
    let full = (grad_bytes / bucket_bytes).floor();
    let rem = grad_bytes - full * bucket_bytes;
    let mut total = full * spec.allreduce_ms(bucket_bytes, world);
    if rem > 0.0 {
        total += spec.allreduce_ms(rem, world);
    }
    total
}

/// Compose one destination's compute time with the cluster collective
/// model. `compute_ms` is the (destination-GPU) single-replica
/// prediction for the per-replica batch `batch_size`; `comm` comes from
/// [`trace_comm`] on the origin trace.
pub fn compose(
    compute_ms: f64,
    batch_size: usize,
    comm: &TraceComm,
    topology: Topology,
    world: usize,
    params: &ClusterParams,
) -> ClusterPrediction {
    let comm_ms = bucketed_allreduce_ms(topology, world, comm.grad_bytes, params.bucket_bytes);
    let overlappable = params.overlap.clamp(0.0, 1.0) * comm.bwd_fraction * compute_ms;
    let exposed_ms = (comm_ms - overlappable).max(0.0);

    let iter_ms = compute_ms + exposed_ms;
    let single_throughput = batch_size as f64 / (compute_ms / 1e3);
    let throughput = world as f64 * batch_size as f64 / (iter_ms / 1e3);
    ClusterPrediction {
        world,
        compute_ms,
        comm_ms,
        exposed_ms,
        iter_ms,
        throughput,
        efficiency: throughput / (world as f64 * single_throughput),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::Device;
    use crate::predict::HybridPredictor;
    use crate::tracker::OperationTracker;

    fn comm_for(model: &str, batch: usize) -> (TraceComm, f64) {
        let graph = crate::models::by_name(model, batch).unwrap();
        let trace = OperationTracker::new(Device::Rtx2070).track(&graph);
        let pred = HybridPredictor::wave_only().predict(&trace, Device::V100);
        (trace_comm(&trace), pred.run_time_ms())
    }

    #[test]
    fn world_one_reproduces_the_compute_prediction_bit_for_bit() {
        let (comm, compute_ms) = comm_for("resnet50", 32);
        for t in [Topology::DGX, Topology::CLOUD] {
            let p = compose(compute_ms, 32, &comm, t, 1, &ClusterParams::default());
            assert_eq!(p.comm_ms, 0.0);
            assert_eq!(p.exposed_ms, 0.0);
            assert_eq!(p.iter_ms.to_bits(), compute_ms.to_bits());
            assert!((p.efficiency - 1.0).abs() < 1e-12);
        }
    }

    #[test]
    fn exposed_time_is_never_negative_and_efficiency_never_exceeds_one() {
        let (comm, compute_ms) = comm_for("gnmt", 32);
        for t in [Topology::DGX, Topology::CLOUD] {
            for world in [1usize, 2, 4, 8, 16, 64, 256] {
                for overlap in [0.0, 0.5, 1.0, 7.0, -3.0] {
                    let params = ClusterParams { overlap, ..Default::default() };
                    let p = compose(compute_ms, 32, &comm, t, world, &params);
                    assert!(p.exposed_ms >= 0.0);
                    assert!(p.iter_ms >= p.compute_ms);
                    assert!(p.efficiency > 0.0 && p.efficiency <= 1.0 + 1e-9);
                }
            }
        }
    }

    #[test]
    fn efficiency_decreases_with_world_size() {
        let (comm, compute_ms) = comm_for("resnet50", 32);
        for t in [Topology::DGX, Topology::CLOUD] {
            let mut prev = 1.0 + 1e-9;
            for world in [1usize, 2, 4, 8] {
                let p = compose(compute_ms, 32, &comm, t, world, &ClusterParams::default());
                assert!(p.efficiency <= prev + 1e-9, "{t} world {world}: {}", p.efficiency);
                prev = p.efficiency;
            }
        }
    }

    #[test]
    fn bucketing_matches_the_bucket_sum() {
        let topo = Topology::CLOUD;
        let spec = topo.spec();
        let bucket = 25.0 * 1024.0 * 1024.0;
        let grad = 2.5 * bucket; // two full buckets + a half
        let expect = 2.0 * spec.allreduce_ms(bucket, 8) + spec.allreduce_ms(0.5 * bucket, 8);
        assert_eq!(bucketed_allreduce_ms(topo, 8, grad, bucket).to_bits(), expect.to_bits());
        // Disabled bucketing = one flat shot.
        assert_eq!(
            bucketed_allreduce_ms(topo, 8, grad, 0.0).to_bits(),
            spec.allreduce_ms(grad, 8).to_bits()
        );
    }

    #[test]
    fn dgx_scales_better_than_cloud() {
        let (comm, compute_ms) = comm_for("gnmt", 32);
        for world in [8usize, 64, 256] {
            let dgx = compose(compute_ms, 32, &comm, Topology::DGX, world, &ClusterParams::default());
            let cloud =
                compose(compute_ms, 32, &comm, Topology::CLOUD, world, &ClusterParams::default());
            assert!(dgx.efficiency > cloud.efficiency, "world {world}");
            assert!(dgx.iter_ms < cloud.iter_ms, "world {world}");
        }
    }

    #[test]
    fn trace_comm_counts_fp32_gradients() {
        let graph = crate::models::by_name("resnet50", 32).unwrap();
        let trace = OperationTracker::new(Device::Rtx2070).track(&graph);
        let comm = trace_comm(&trace);
        let params: u64 = trace.ops.iter().map(|o| o.op.kind.parameter_count()).sum();
        assert_eq!(comm.grad_bytes.to_bits(), trace
            .ops
            .iter()
            .map(|o| o.op.kind.parameter_count() as f64 * 4.0)
            .sum::<f64>()
            .to_bits());
        assert!(params > 10_000_000, "resnet50 has >10M parameters");
        assert!(comm.bwd_fraction > 0.0 && comm.bwd_fraction < 1.0);
    }
}
