//! The compiled-plan IR: everything destination-independent, hoisted.
//!
//! Habitat's core loop (Eq. 1/2, §3.3) scales every kernel of an origin
//! trace onto each destination GPU. The scaling itself is cheap
//! arithmetic — but the naive pipeline re-pays destination-independent
//! work inside the per-destination loop: wave-size lookups through the
//! global [`crate::engine::memo::WaveTable`] mutex, roofline γ selection
//! per kernel per destination, and MLP feature-vector construction per
//! op per destination. When one trace fans out to N GPUs per `rank`
//! RPC, that per-destination cost is the product that multiplies.
//!
//! [`AnalyzedPlan`] is the fix: a flat structure-of-arrays arena built
//! **once** per trace that hoists everything that does not depend on the
//! destination *choice*:
//!
//! * per-kernel launch metadata (grid blocks, measured time, arithmetic
//!   intensity, AMP/tensor-core eligibility) in one flat arena, with
//!   op→kernel index ranges for the forward and backward passes;
//! * wave sizes for **all** `(launch shape, device)` pairs, resolved in
//!   one batched pass at build time — the evaluate loop never touches
//!   the wave table (no lock, no hash);
//! * effective γ per `(kernel, device)` with the metrics-availability
//!   policy (§4.2) baked in at build time;
//! * the Daydream AMP factor per `(op, device)` (§6.1.2);
//! * per-op MLP feature vectors, grouped by MLP family in dispatch
//!   order.
//!
//! The per-destination evaluators
//! ([`crate::predict::HybridPredictor::evaluate`]) are thin loops over
//! these arrays: pure scaling arithmetic, bit-identical to the legacy
//! trace-walking path ([`crate::predict::HybridPredictor::predict`]),
//! which is kept as the reference implementation and pinned against the
//! plan path by the golden regression tests.
//!
//! Compilation itself splits into a cheap destination-independent
//! **prefix** (one walk over the trace: kernel arena, launch-shape
//! dedup, MLP features) and the expensive per-device **lanes** (wave
//! sizes, γ, AMP factors — one independent row per registry device).
//! [`AnalyzedPlan::build_parallel`] fills those rows on the shared
//! [`WorkerPool`] with the same work-claiming, deadlock-free shape as
//! the engine's fan-out; [`AnalyzedPlan::build`] is the serial
//! reference, bit-identical by construction. The same prefix/lane split
//! powers the persistent store (`engine::store`): a restored plan
//! reruns the prefix from the decoded trace and installs the stored
//! lane tables as raw bit patterns.

use std::collections::{BTreeMap, HashMap};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, RwLock};

use crate::engine::pool::WorkerPool;

use crate::device::{registry, Device, GpuSpec, LaunchConfig};
use crate::engine::memo::WaveTable;
use crate::lowering::Precision;
use crate::opgraph::MlpOp;
use crate::predict::roofline::{self, MetricsPolicy};
use crate::predict::{amp, PredictedOp, PredictedTrace};
use crate::tracker::Trace;
use crate::util::simdf64;

/// A trace and its compiled plan, produced together by
/// [`crate::tracker::OperationTracker::track_analyzed`] and cached
/// together by the engine. Cloning is two `Arc` bumps.
#[derive(Clone)]
pub struct AnalyzedTrace {
    pub trace: Arc<Trace>,
    pub plan: Arc<AnalyzedPlan>,
}

/// One MLP dispatch group: every op of the trace predicted by the same
/// MLP family, in trace order, with its feature rows prebuilt.
#[derive(Debug, Clone)]
pub struct MlpGroup {
    pub op: MlpOp,
    /// Positions (in plan-op order) of the ops this group overwrites.
    pub slots: Vec<usize>,
    /// One feature row per slot (see [`crate::opgraph::Op::mlp_features`]).
    pub features: Vec<Vec<f64>>,
}

/// The flat, destination-independent compilation of one tracked trace.
///
/// All per-device tables are dense over the [`registry`] **snapshot
/// taken at build time** ([`AnalyzedPlan::n_devices`]), indexed by
/// [`Device::index`]; per-kernel arrays are flattened in prediction
/// order (for each op: forward kernels, then backward kernels).
///
/// Open-world coherence: a device registered *after* this plan was
/// compiled is outside the dense tables, so its lane is **appended
/// once** — computed from the retained per-kernel metadata (same
/// formulas, same shared wave table — bit-identical to a plan rebuilt
/// after the registration) and cached in [`AnalyzedPlan::extend_device`]
/// extension slots. Cached plans therefore never go stale when the
/// registry grows, and after the one-time extension the new device is
/// served from its appended lane at dense-table speed.
pub struct AnalyzedPlan {
    pub model: String,
    pub batch_size: usize,
    pub origin: Device,
    /// Precision the origin trace was *tracked* at.
    pub precision: Precision,
    /// Measured iteration time on the origin, ms.
    pub origin_run_time_ms: f64,

    // --- per-op arrays (len = n_ops) --------------------------------
    op_index: Vec<usize>,
    op_name: Vec<String>,
    op_short_name: Vec<&'static str>,
    /// Flat-kernel range starts; `kern_start[o]..kern_fwd_end[o]` is the
    /// op's forward pass, `kern_fwd_end[o]..kern_end[o]` its backward.
    kern_start: Vec<u32>,
    kern_fwd_end: Vec<u32>,
    kern_end: Vec<u32>,

    // --- per-kernel arrays (len = n_kernels) ------------------------
    time_ms: Vec<f64>,
    /// Grid blocks (`B` of Eq. 1), clamped to ≥ 1.
    blocks: Vec<u64>,
    /// Index into the deduplicated launch-shape tables.
    shape_idx: Vec<u32>,
    /// Arithmetic intensity (FLOPs/byte) — retained so lanes for
    /// devices registered after this plan was built can be computed.
    intensity: Vec<f64>,
    /// Tensor-core eligibility (AMP-lane computation for new devices).
    tensor_core: Vec<bool>,
    /// Metrics availability under the build policy (γ fallback mask).
    profiled: Vec<bool>,

    // --- per-shape arrays (len = n_shapes) --------------------------
    /// Deduplicated launch shapes (wave-size lookups for new devices).
    shapes: Vec<LaunchConfig>,
    /// Wave size on the origin device, clamped to ≥ 1.
    wave_origin: Vec<u64>,
    /// Wave size on every snapshot device:
    /// `[device.index() * n_shapes + shape]`.
    wave_dest: Vec<u64>,

    // --- per-(device, kernel) / per-(device, op) tables -------------
    /// Registry size when this plan was compiled: the extent of the
    /// dense per-device tables below.
    n_devices: usize,
    /// Effective γ with the metrics policy baked in (γ = 1 fallback for
    /// unprofiled kernels): `[device.index() * n_kernels + kernel]`.
    gamma: Vec<f64>,
    /// Daydream AMP factor per op: `[device.index() * n_ops + op]`.
    amp_op_factor: Vec<f64>,

    // --- MLP dispatch -----------------------------------------------
    mlp_groups: Vec<MlpGroup>,

    // --- post-snapshot extension lanes ------------------------------
    /// Lanes for devices registered after the snapshot, appended once
    /// by [`AnalyzedPlan::extend_device`]; slot `i` holds device index
    /// `n_devices + i`. Reads are a lock + two `Arc` bumps — no
    /// allocation, no recompute.
    ext: RwLock<Vec<Option<ExtLane>>>,
}

/// One post-snapshot device's computed lanes, shared via `Arc` so
/// concurrent sweeps can hold a row without cloning it.
#[derive(Clone)]
struct ExtLane {
    gamma: Arc<[f64]>,
    wave: Arc<[u64]>,
    amp: Arc<[f64]>,
}

/// One device's policy-masked γ per kernel, appended to `out`. Shared
/// by the dense build pass and the computed lane for devices registered
/// after a plan's snapshot (keeps the two paths bit-identical).
fn gamma_row_into(intensity: &[f64], profiled: &[bool], spec: &GpuSpec, out: &mut Vec<f64>) {
    for k in 0..intensity.len() {
        out.push(if profiled[k] { roofline::gamma(intensity[k], spec) } else { 1.0 });
    }
}

/// One device's Daydream AMP factor per op (time-weighted mean over the
/// op's kernels, raw γ — never the policy fallback), appended to `out`.
#[allow(clippy::too_many_arguments)]
fn amp_row_into(
    time_ms: &[f64],
    intensity: &[f64],
    tensor_core: &[bool],
    kern_start: &[u32],
    kern_fwd_end: &[u32],
    kern_end: &[u32],
    spec: &GpuSpec,
    out: &mut Vec<f64>,
) {
    for o in 0..kern_start.len() {
        let (start, mid, end) = (
            kern_start[o] as usize,
            kern_fwd_end[o] as usize,
            kern_end[o] as usize,
        );
        let fwd_ms: f64 = time_ms[start..mid].iter().sum();
        let bwd_ms: f64 = time_ms[mid..end].iter().sum();
        let total = fwd_ms + bwd_ms;
        if total <= 0.0 {
            out.push(1.0);
            continue;
        }
        let weighted: f64 = (start..end)
            .map(|k| {
                let g = roofline::gamma(intensity[k], spec);
                amp::amp_factor(g, tensor_core[k], spec) * time_ms[k]
            })
            .sum();
        out.push(weighted / total);
    }
}

/// One device's destination-dependent lane rows: wave size per shape,
/// γ per kernel, AMP factor per op. The unit of work the parallel build
/// distributes and the extension path appends.
struct DeviceRow {
    wave: Vec<u64>,
    gamma: Vec<f64>,
    amp: Vec<f64>,
}

/// Compute one device's full lane row with the shared helpers — the
/// single code path behind the serial build loop, the parallel build
/// workers, and [`AnalyzedPlan::extend_device`], so all three are
/// bit-identical by construction.
#[allow(clippy::too_many_arguments)]
fn lane_row(
    shapes: &[LaunchConfig],
    intensity: &[f64],
    profiled: &[bool],
    time_ms: &[f64],
    tensor_core: &[bool],
    kern_start: &[u32],
    kern_fwd_end: &[u32],
    kern_end: &[u32],
    spec: &GpuSpec,
) -> DeviceRow {
    let table = WaveTable::global();
    let mut row = DeviceRow {
        wave: Vec::with_capacity(shapes.len()),
        gamma: Vec::with_capacity(intensity.len()),
        amp: Vec::with_capacity(kern_start.len()),
    };
    for s in shapes {
        row.wave.push(table.wave_size(spec, s).max(1));
    }
    gamma_row_into(intensity, profiled, spec, &mut row.gamma);
    amp_row_into(
        time_ms,
        intensity,
        tensor_core,
        kern_start,
        kern_fwd_end,
        kern_end,
        spec,
        &mut row.amp,
    );
    row
}

/// A lane slice: borrowed from the dense tables for snapshot devices,
/// an `Arc` bump of the appended extension row for later ones.
enum Lane<'a, T> {
    Dense(&'a [T]),
    Ext(Arc<[T]>),
}

impl<T> std::ops::Deref for Lane<'_, T> {
    type Target = [T];

    fn deref(&self) -> &[T] {
        match self {
            Lane::Dense(s) => s,
            Lane::Ext(a) => a,
        }
    }
}

/// One destination's Daydream AMP factor row (see
/// [`AnalyzedPlan::amp_factors`]): dereferences to `[f64]`, one factor
/// per op.
pub enum AmpFactors<'a> {
    /// Borrowed from the dense table (snapshot device).
    Dense(&'a [f64]),
    /// The appended extension lane (post-snapshot device).
    Ext(Arc<[f64]>),
}

impl std::ops::Deref for AmpFactors<'_> {
    type Target = [f64];

    fn deref(&self) -> &[f64] {
        match self {
            AmpFactors::Dense(s) => s,
            AmpFactors::Ext(a) => a,
        }
    }
}

impl AsRef<[f64]> for AmpFactors<'_> {
    fn as_ref(&self) -> &[f64] {
        self
    }
}

/// One destination's view of a plan: γ per kernel and wave size per
/// launch shape. Borrowed slices of the dense tables for devices inside
/// the plan's registry snapshot; the appended extension lane (computed
/// once, same helpers, same wave table) for devices registered after
/// it.
pub struct DeviceLanes<'a> {
    gamma: Lane<'a, f64>,
    wave: Lane<'a, u64>,
    shape_idx: &'a [u32],
}

impl DeviceLanes<'_> {
    /// Effective γ of a kernel (policy fallback baked in).
    pub fn gamma(&self, kernel: usize) -> f64 {
        self.gamma[kernel]
    }

    /// Wave size of a kernel's launch shape on the destination.
    pub fn wave_dest(&self, kernel: usize) -> u64 {
        self.wave[self.shape_idx[kernel] as usize]
    }
}

/// Reusable arena for the kernel-major batched evaluator
/// ([`crate::predict::HybridPredictor::evaluate_batch`]).
///
/// One sweep evaluates one plan against a whole destination set: the
/// arena holds the dense `kernels × dests` lane matrices the sweep
/// reads (γ, wave ratio, Eq. 1 wave counts), the `ops × dests` time
/// accumulator it writes, and the per-destination dedup/expansion map.
/// Buffers are `clear()` + `resize()`d each sweep, so capacity is
/// retained: after the first sweep of a given `(plan, dests)` shape,
/// **steady-state sweeps perform zero heap allocation** (pinned by
/// `rust/tests/batched_alloc.rs`). Destinations registered after the
/// plan's snapshot pay a one-time [`AnalyzedPlan::extend_device`]
/// computation on first touch; after that their appended lane is read
/// by `Arc` bump and the sweep stays allocation-free. The engine pools
/// one arena per thread ([`crate::engine::pool::with_scratch`]).
///
/// Every destination-indexed matrix row is padded to an internal
/// `stride` — the unique-destination count rounded up to the SIMD lane
/// width ([`crate::util::simdf64::LANES`]) — so the vector backend
/// consumes whole chunks without a tail branch. Pad lanes hold the
/// identity values of each lane (ratio 1, γ 0, wave count 1): they run
/// through the same arithmetic as real destinations, stay finite, and
/// are never read back (every reader maps caller indices through the
/// dedup slot map, which only produces slots `< n_unique`).
#[derive(Default)]
pub struct EvalScratch {
    /// Unique destinations of the current sweep, first-occurrence order.
    pub(crate) dests: Vec<Device>,
    /// Caller index → slot in [`EvalScratch::dests`] (dedup expansion).
    pub(crate) slot: Vec<usize>,
    /// Row stride of every destination-indexed matrix: `n_unique`
    /// rounded up to the SIMD lane width.
    pub(crate) stride: usize,
    /// `D_o/D_d` per unique destination (padded, pad = 1).
    pub(crate) bw: Vec<f64>,
    /// `C_o/C_d` per unique destination (padded, pad = 1).
    pub(crate) clock: Vec<f64>,
    /// γ, dense `[kernel * stride + dest]` (transposed so the batched
    /// inner loop over destinations is contiguous; pad = 0).
    pub(crate) gamma_t: Vec<f64>,
    /// Wave ratio `W_o/W_d`, same `kernels × stride` layout (pad = 1).
    pub(crate) wave_t: Vec<f64>,
    /// `⌈B/W_d⌉` per `(kernel, dest)` — filled for Eq. 1 sweeps only
    /// (pad = 1).
    pub(crate) waves_d_t: Vec<f64>,
    /// `⌈B/W_o⌉` per kernel — Eq. 1 sweeps only.
    pub(crate) waves_o: Vec<f64>,
    /// Per-kernel working lane: `wave · clock` (Eq. 2) or `bw / wave`
    /// (Eq. 1), one exact IEEE op per element.
    pub(crate) wc: Vec<f64>,
    /// Per-kernel `powf` factor lanes of the wave-scaling expressions
    /// (see [`crate::predict::wave::eq2_factor_lanes`] /
    /// [`crate::predict::wave::eq1_factor_lanes`]).
    pub(crate) p1: Vec<f64>,
    pub(crate) p2: Vec<f64>,
    /// Accumulated op times, `[op * stride + dest]`.
    pub(crate) acc: Vec<f64>,
    /// Whether an MLP overwrote the op, `[op * stride + dest]`.
    pub(crate) mlp_hit: Vec<bool>,
    /// MLP fallback count per unique destination.
    pub(crate) fallbacks: Vec<usize>,
    /// AMP-row staging buffer for destinations registered after the
    /// plan's snapshot (the appended lane is copied in so the sweep can
    /// borrow it; reused across sweeps like everything else).
    pub(crate) lane_amp: Vec<f64>,
    /// AMP factors transposed to the accumulator's `[op * stride +
    /// dest]` layout (pad = 1), staged so the factor application is a
    /// per-op-row vector multiply.
    pub(crate) amp_t: Vec<f64>,
    /// Ops in the last sweep's plan (row count of `acc`).
    pub(crate) n_ops: usize,
    /// Whether the last sweep had to grow any buffer (a steady-state
    /// sweep over a previously seen shape must not).
    pub(crate) grew: bool,
}

/// `clear` + `resize` that records whether the buffer had to grow —
/// steady-state sweeps reuse capacity and never allocate.
fn ensure<T: Copy>(v: &mut Vec<T>, n: usize, fill: T, grew: &mut bool) {
    if v.capacity() < n {
        *grew = true;
    }
    v.clear();
    v.resize(n, fill);
}

impl EvalScratch {
    pub fn new() -> Self {
        Self::default()
    }

    /// Start a sweep: dedup `dests` into unique slots + expansion map.
    pub(crate) fn begin(&mut self, dests: &[Device]) {
        self.grew =
            self.dests.capacity() < dests.len() || self.slot.capacity() < dests.len();
        self.dests.clear();
        self.slot.clear();
        for &d in dests {
            // Linear scan, not a hash map: destination sets are small
            // (tens), and the sweep itself must stay allocation-free.
            match self.dests.iter().position(|&u| u == d) {
                Some(i) => self.slot.push(i),
                None => {
                    self.slot.push(self.dests.len());
                    self.dests.push(d);
                }
            }
        }
        self.stride = self.dests.len().next_multiple_of(simdf64::LANES);
    }

    /// Unique destinations in the last sweep.
    pub fn n_unique(&self) -> usize {
        self.dests.len()
    }

    /// Caller destinations in the last sweep (before dedup).
    pub fn n_dests(&self) -> usize {
        self.slot.len()
    }

    /// Whether the last sweep had to grow a buffer. A steady-state sweep
    /// (same plan shape, same destination-set size) returns `false`.
    pub fn grew(&self) -> bool {
        self.grew
    }

    /// Predicted time of op `op` for caller destination `dest_idx`
    /// (an index into the `dests` slice passed to the sweep).
    pub fn op_time_ms(&self, dest_idx: usize, op: usize) -> f64 {
        self.acc[op * self.stride + self.slot[dest_idx]]
    }

    /// Predicted iteration time for caller destination `dest_idx`, ms —
    /// summed in op order, bit-identical to
    /// [`PredictedTrace::run_time_ms`] on the materialized trace.
    pub fn run_time_ms(&self, dest_idx: usize) -> f64 {
        let stride = self.stride;
        let di = self.slot[dest_idx];
        (0..self.n_ops).map(|o| self.acc[o * stride + di]).sum()
    }

    /// Predicted throughput (samples/s) for caller destination
    /// `dest_idx` — the exact [`PredictedTrace::throughput`] expression.
    pub fn throughput(&self, dest_idx: usize, batch_size: usize) -> f64 {
        batch_size as f64 / (self.run_time_ms(dest_idx) / 1e3)
    }

    /// MLP fallback count for caller destination `dest_idx`.
    pub fn mlp_fallbacks(&self, dest_idx: usize) -> usize {
        self.fallbacks[self.slot[dest_idx]]
    }

    /// Build the full [`PredictedTrace`] for caller destination
    /// `dest_idx` — field-for-field what the scalar evaluator returns
    /// (this is the only allocating step of the batched path).
    pub fn materialize(&self, plan: &AnalyzedPlan, dest_idx: usize) -> PredictedTrace {
        let stride = self.stride;
        let di = self.slot[dest_idx];
        let ops = (0..self.n_ops)
            .map(|o| PredictedOp {
                index: plan.op_index[o],
                name: plan.op_name[o].clone(),
                short_name: plan.op_short_name[o].to_string(),
                time_ms: self.acc[o * stride + di],
                method: if self.mlp_hit[o * stride + di] {
                    crate::predict::PredictionMethod::Mlp
                } else {
                    crate::predict::PredictionMethod::WaveScaling
                },
            })
            .collect();
        PredictedTrace {
            model: plan.model.clone(),
            batch_size: plan.batch_size,
            origin: plan.origin,
            dest: self.dests[di],
            ops,
            mlp_fallbacks: self.fallbacks[di],
        }
    }
}

/// The destination-independent prefix of a plan: one walk over the
/// trace (kernel arena, launch-shape dedup, policy mask, MLP features).
/// Shared by the serial build, the parallel build, and the store's
/// restore path ([`AnalyzedPlan::from_parts`]) so the three cannot
/// drift.
struct PlanPrefix {
    op_index: Vec<usize>,
    op_name: Vec<String>,
    op_short_name: Vec<&'static str>,
    kern_start: Vec<u32>,
    kern_fwd_end: Vec<u32>,
    kern_end: Vec<u32>,
    time_ms: Vec<f64>,
    blocks: Vec<u64>,
    shape_idx: Vec<u32>,
    profiled: Vec<bool>,
    intensity: Vec<f64>,
    tensor_core: Vec<bool>,
    shapes: Vec<LaunchConfig>,
    mlp_groups: Vec<MlpGroup>,
}

fn plan_prefix(trace: &Trace, policy: &MetricsPolicy) -> PlanPrefix {
    let n_ops = trace.ops.len();
    let profiled_set = policy.profiled_kernels(trace);

    let mut op_index = Vec::with_capacity(n_ops);
    let mut op_name = Vec::with_capacity(n_ops);
    let mut op_short_name = Vec::with_capacity(n_ops);
    let mut kern_start = Vec::with_capacity(n_ops);
    let mut kern_fwd_end = Vec::with_capacity(n_ops);
    let mut kern_end = Vec::with_capacity(n_ops);

    let mut time_ms = Vec::new();
    let mut blocks = Vec::new();
    let mut shape_idx: Vec<u32> = Vec::new();
    let mut profiled: Vec<bool> = Vec::new();
    let mut intensity: Vec<f64> = Vec::new();
    let mut tensor_core: Vec<bool> = Vec::new();

    // Launch-shape dedup: wave sizes depend only on this projection
    // of the launch configuration (grid size excluded).
    let mut shape_of: HashMap<(u32, u32, u32), u32> = HashMap::new();
    let mut shapes: Vec<LaunchConfig> = Vec::new();

    let mut mlp_items: BTreeMap<MlpOp, (Vec<usize>, Vec<Vec<f64>>)> = BTreeMap::new();

    for (slot, t) in trace.ops.iter().enumerate() {
        op_index.push(t.index);
        op_name.push(t.op.name.clone());
        op_short_name.push(t.op.kind.short_name());
        kern_start.push(time_ms.len() as u32);
        for (pass_idx, pass) in [&t.fwd, &t.bwd].into_iter().enumerate() {
            for m in pass {
                let launch = &m.kernel.launch;
                let key = (
                    launch.threads_per_block,
                    launch.regs_per_thread,
                    launch.smem_per_block,
                );
                let si = *shape_of.entry(key).or_insert_with(|| {
                    shapes.push(*launch);
                    (shapes.len() - 1) as u32
                });
                time_ms.push(m.time_ms);
                blocks.push(launch.grid_blocks.max(1));
                shape_idx.push(si);
                profiled.push(
                    profiled_set
                        .as_ref()
                        .map_or(true, |set| set.contains(&roofline::cache_key(&m.kernel))),
                );
                intensity.push(m.kernel.arith_intensity());
                tensor_core.push(m.kernel.tensor_core_eligible);
            }
            if pass_idx == 0 {
                kern_fwd_end.push(time_ms.len() as u32);
            }
        }
        kern_end.push(time_ms.len() as u32);

        if let Some((mlp_op, features)) = t.op.mlp_features() {
            let entry = mlp_items.entry(mlp_op).or_default();
            entry.0.push(slot);
            entry.1.push(features);
        }
    }

    let mlp_groups = mlp_items
        .into_iter()
        .map(|(op, (slots, features))| MlpGroup { op, slots, features })
        .collect();

    PlanPrefix {
        op_index,
        op_name,
        op_short_name,
        kern_start,
        kern_fwd_end,
        kern_end,
        time_ms,
        blocks,
        shape_idx,
        profiled,
        intensity,
        tensor_core,
        shapes,
        mlp_groups,
    }
}

impl PlanPrefix {
    fn lane_row(&self, spec: &GpuSpec) -> DeviceRow {
        lane_row(
            &self.shapes,
            &self.intensity,
            &self.profiled,
            &self.time_ms,
            &self.tensor_core,
            &self.kern_start,
            &self.kern_fwd_end,
            &self.kern_end,
            spec,
        )
    }

    /// The per-kernel inputs a lane row needs, cloned so pool helpers
    /// (`'static` jobs) can read them while the caller keeps the
    /// originals for the final plan.
    fn lane_inputs(&self) -> PlanPrefix {
        PlanPrefix {
            op_index: Vec::new(),
            op_name: Vec::new(),
            op_short_name: Vec::new(),
            kern_start: self.kern_start.clone(),
            kern_fwd_end: self.kern_fwd_end.clone(),
            kern_end: self.kern_end.clone(),
            time_ms: self.time_ms.clone(),
            blocks: Vec::new(),
            shape_idx: Vec::new(),
            profiled: self.profiled.clone(),
            intensity: self.intensity.clone(),
            tensor_core: self.tensor_core.clone(),
            shapes: self.shapes.clone(),
            mlp_groups: Vec::new(),
        }
    }
}

/// The dense per-device tables of a plan — the expensive product of
/// compilation, and exactly what the persistent store writes to disk.
/// A restored plan reruns the cheap prefix walk from the decoded trace
/// and installs these bit-preserved tables instead of recomputing them.
pub(crate) struct DenseLanes {
    pub(crate) n_devices: usize,
    pub(crate) wave_origin: Vec<u64>,
    pub(crate) wave_dest: Vec<u64>,
    pub(crate) gamma: Vec<f64>,
    pub(crate) amp_op_factor: Vec<f64>,
}

/// Work-claiming parallel fill of the per-device lane rows: an atomic
/// cursor over device indices, helpers submitted with
/// [`WorkerPool::try_execute`] (never blocking — a build running *on* a
/// pool worker still makes progress because the caller always claims
/// too), results sent back keyed by device index so assembly order is
/// deterministic.
struct LaneFanOut {
    inputs: PlanPrefix,
    devices: Vec<Device>,
    next: AtomicUsize,
    tx: mpsc::Sender<(usize, std::thread::Result<DeviceRow>)>,
}

impl LaneFanOut {
    fn run(&self) {
        loop {
            let d = self.next.fetch_add(1, Ordering::Relaxed);
            if d >= self.devices.len() {
                break;
            }
            let spec = self.devices[d].spec();
            let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                self.inputs.lane_row(spec)
            }));
            if self.tx.send((d, result)).is_err() {
                break;
            }
        }
    }
}

impl AnalyzedPlan {
    /// Compile a tracked trace into a plan. `policy` is the metrics-
    /// availability policy of the predictor that will evaluate the plan
    /// (γ selection is baked in here, so the plan must be rebuilt if the
    /// policy changes).
    ///
    /// This is the one place the pipeline touches the shared
    /// [`WaveTable`]: wave sizes for every `(launch shape, device)` pair
    /// are resolved in a single batched pass.
    pub fn build(trace: &Trace, policy: &MetricsPolicy) -> AnalyzedPlan {
        Self::build_with_pool(trace, policy, None).0
    }

    /// [`AnalyzedPlan::build`] with the per-device lane rows (wave
    /// sizes, γ, AMP factors — including the memoized [`WaveTable`]
    /// batch fill) computed in parallel on the shared pool. Returns the
    /// plan and the number of work-claimed lane chunks (one per
    /// snapshot device; 0 when the build fell back to the serial path).
    /// Bit-identical to the serial build: every row is produced by the
    /// same `lane_row` helper and assembled in device-index order.
    pub fn build_parallel(
        trace: &Trace,
        policy: &MetricsPolicy,
        pool: &WorkerPool,
    ) -> (AnalyzedPlan, u64) {
        Self::build_with_pool(trace, policy, Some(pool))
    }

    fn build_with_pool(
        trace: &Trace,
        policy: &MetricsPolicy,
        pool: Option<&WorkerPool>,
    ) -> (AnalyzedPlan, u64) {
        let prefix = plan_prefix(trace, policy);

        // Snapshot the open-world registry: runtime-registered devices
        // get dense lanes in every plan built from here on.
        let devices = registry::all_devices();
        let n_devices = devices.len();

        // Batched wave-size resolution for the origin, through the
        // shared memo table (so the simulator and any concurrent engine
        // still benefit from the same entries).
        let table = WaveTable::global();
        let origin_spec = trace.origin.spec();
        let wave_origin: Vec<u64> = prefix
            .shapes
            .iter()
            .map(|s| table.wave_size(origin_spec, s).max(1))
            .collect();

        // Per-device lane rows: the raw γ per kernel feeds both the
        // policy-masked γ table (γ = 1 fallback for unprofiled kernels —
        // identical to the legacy per-destination selection) and the
        // Daydream AMP factor per op (the time-weighted mean of
        // per-kernel AMP factors, exactly as `predict::amp::amp_transform`
        // computes it — the AMP transform always uses the raw γ, never
        // the fallback). The same helpers serve the post-snapshot
        // extension lanes, so no path can drift.
        let (rows, chunks) = match pool {
            Some(pool) if n_devices >= 2 => {
                let (tx, rx) = mpsc::channel();
                let shared = Arc::new(LaneFanOut {
                    inputs: prefix.lane_inputs(),
                    devices: devices.clone(),
                    next: AtomicUsize::new(0),
                    tx,
                });
                let helpers = pool.size().min(n_devices - 1);
                for _ in 0..helpers {
                    let state = Arc::clone(&shared);
                    if pool.try_execute(move || state.run()).is_err() {
                        break; // full queue: the caller claims the rest
                    }
                }
                shared.run();
                drop(shared);
                let mut rows: Vec<Option<DeviceRow>> = (0..n_devices).map(|_| None).collect();
                for _ in 0..n_devices {
                    let (d, result) = rx.recv().expect("every claimed lane row reports");
                    match result {
                        Ok(row) => rows[d] = Some(row),
                        Err(payload) => std::panic::resume_unwind(payload),
                    }
                }
                let rows: Vec<DeviceRow> =
                    rows.into_iter().map(|r| r.expect("lane row filled")).collect();
                (rows, n_devices as u64)
            }
            _ => {
                let rows = devices.iter().map(|dev| prefix.lane_row(dev.spec())).collect();
                (rows, 0)
            }
        };

        let (nk, no, ns) = (
            prefix.time_ms.len(),
            prefix.op_index.len(),
            prefix.shapes.len(),
        );
        let mut wave_dest = Vec::with_capacity(n_devices * ns);
        let mut gamma = Vec::with_capacity(n_devices * nk);
        let mut amp_op_factor = Vec::with_capacity(n_devices * no);
        for row in rows {
            wave_dest.extend(row.wave);
            gamma.extend(row.gamma);
            amp_op_factor.extend(row.amp);
        }

        let lanes = DenseLanes {
            n_devices,
            wave_origin,
            wave_dest,
            gamma,
            amp_op_factor,
        };
        (Self::assemble(trace, prefix, lanes), chunks)
    }

    /// Reassemble a plan from its decoded trace plus stored dense lane
    /// tables — the persistent store's restore path. Reruns the same
    /// prefix walk as [`AnalyzedPlan::build`]; the lanes are the only
    /// part read from disk, installed as raw bit patterns, so a
    /// restored plan is bit-identical to a freshly compiled one by
    /// construction. Dimension mismatches (stale record, corrupt
    /// length) are rejected.
    pub(crate) fn from_parts(
        trace: &Trace,
        policy: &MetricsPolicy,
        lanes: DenseLanes,
    ) -> anyhow::Result<AnalyzedPlan> {
        let prefix = plan_prefix(trace, policy);
        let (nk, no, ns) = (
            prefix.time_ms.len(),
            prefix.op_index.len(),
            prefix.shapes.len(),
        );
        anyhow::ensure!(
            lanes.n_devices <= registry::device_count(),
            "stored snapshot has {} devices, registry only {}",
            lanes.n_devices,
            registry::device_count()
        );
        anyhow::ensure!(lanes.wave_origin.len() == ns, "wave_origin length mismatch");
        anyhow::ensure!(
            lanes.wave_dest.len() == lanes.n_devices * ns,
            "wave_dest length mismatch"
        );
        anyhow::ensure!(
            lanes.gamma.len() == lanes.n_devices * nk,
            "gamma length mismatch"
        );
        anyhow::ensure!(
            lanes.amp_op_factor.len() == lanes.n_devices * no,
            "amp factor length mismatch"
        );
        Ok(Self::assemble(trace, prefix, lanes))
    }

    fn assemble(trace: &Trace, prefix: PlanPrefix, lanes: DenseLanes) -> AnalyzedPlan {
        AnalyzedPlan {
            model: trace.model.clone(),
            batch_size: trace.batch_size,
            origin: trace.origin,
            precision: trace.precision,
            origin_run_time_ms: trace.run_time_ms(),
            op_index: prefix.op_index,
            op_name: prefix.op_name,
            op_short_name: prefix.op_short_name,
            kern_start: prefix.kern_start,
            kern_fwd_end: prefix.kern_fwd_end,
            kern_end: prefix.kern_end,
            time_ms: prefix.time_ms,
            blocks: prefix.blocks,
            shape_idx: prefix.shape_idx,
            intensity: prefix.intensity,
            tensor_core: prefix.tensor_core,
            profiled: prefix.profiled,
            shapes: prefix.shapes,
            wave_origin: lanes.wave_origin,
            wave_dest: lanes.wave_dest,
            n_devices: lanes.n_devices,
            gamma: lanes.gamma,
            amp_op_factor: lanes.amp_op_factor,
            mlp_groups: prefix.mlp_groups,
            ext: RwLock::new(Vec::new()),
        }
    }

    pub fn n_ops(&self) -> usize {
        self.op_index.len()
    }

    pub fn n_kernels(&self) -> usize {
        self.time_ms.len()
    }

    pub fn n_shapes(&self) -> usize {
        self.wave_origin.len()
    }

    pub fn op_index(&self, op: usize) -> usize {
        self.op_index[op]
    }

    pub fn op_name(&self, op: usize) -> &str {
        &self.op_name[op]
    }

    pub fn op_short_name(&self, op: usize) -> &'static str {
        self.op_short_name[op]
    }

    /// The op's flat kernel range (forward followed by backward).
    pub fn kernel_range(&self, op: usize) -> std::ops::Range<usize> {
        self.kern_start[op] as usize..self.kern_end[op] as usize
    }

    /// The op's forward/backward boundary within [`Self::kernel_range`].
    pub fn fwd_end(&self, op: usize) -> usize {
        self.kern_fwd_end[op] as usize
    }

    pub fn kernel_time_ms(&self, kernel: usize) -> f64 {
        self.time_ms[kernel]
    }

    pub fn kernel_blocks(&self, kernel: usize) -> u64 {
        self.blocks[kernel]
    }

    /// Registry size when this plan was compiled (the extent of the
    /// dense per-device tables).
    pub fn n_devices(&self) -> usize {
        self.n_devices
    }

    /// Wave size of a kernel's launch shape on the origin device.
    pub fn wave_origin(&self, kernel: usize) -> u64 {
        self.wave_origin[self.shape_idx[kernel] as usize]
    }

    /// Wave size of a kernel's launch shape on `dest` (precomputed for
    /// snapshot devices; read from the appended extension lane — or,
    /// before any extension, resolved through the shared wave table —
    /// for devices registered after the snapshot).
    pub fn wave_dest(&self, kernel: usize, dest: Device) -> u64 {
        let s = self.shape_idx[kernel] as usize;
        if dest.index() < self.n_devices {
            self.wave_dest[dest.index() * self.n_shapes() + s]
        } else if let Some(lane) = self.ext_lane(dest) {
            lane.wave[s]
        } else {
            WaveTable::global().wave_size(dest.spec(), &self.shapes[s]).max(1)
        }
    }

    /// Effective γ of a kernel on `dest` (policy fallback baked in).
    pub fn gamma(&self, kernel: usize, dest: Device) -> f64 {
        if dest.index() < self.n_devices {
            self.gamma[dest.index() * self.n_kernels() + kernel]
        } else if let Some(lane) = self.ext_lane(dest) {
            lane.gamma[kernel]
        } else if self.profiled[kernel] {
            roofline::gamma(self.intensity[kernel], dest.spec())
        } else {
            1.0
        }
    }

    /// Slot of `dest` in the extension-lane table, if it lies beyond
    /// the dense snapshot.
    fn ext_slot(&self, dest: Device) -> Option<usize> {
        dest.index().checked_sub(self.n_devices)
    }

    /// The appended extension lane for a post-snapshot `dest`, if one
    /// has been computed. Two `Arc` bumps under a read lock.
    fn ext_lane(&self, dest: Device) -> Option<ExtLane> {
        let i = self.ext_slot(dest)?;
        self.ext.read().unwrap().get(i).and_then(|l| l.clone())
    }

    /// Append the computed lane for a device registered after this
    /// plan's snapshot, once: γ per kernel, wave size per shape, AMP
    /// factor per op — the same `lane_row` helper as the dense build,
    /// so the extension is bit-identical to a plan rebuilt after the
    /// registration. Returns `true` if this call did the work; `false`
    /// for snapshot devices and already-extended lanes (idempotent —
    /// concurrent extenders compute identical rows and the first insert
    /// wins). The engine calls this from `register_device` so existing
    /// cached plans grow incrementally instead of recomputing lanes
    /// inside every sweep.
    pub fn extend_device(&self, dest: Device) -> bool {
        let Some(i) = self.ext_slot(dest) else {
            return false;
        };
        if self.ext.read().unwrap().get(i).is_some_and(|l| l.is_some()) {
            return false;
        }
        // Compute outside the lock: the row is deterministic, so a
        // concurrent winner stored the same bits.
        let row = lane_row(
            &self.shapes,
            &self.intensity,
            &self.profiled,
            &self.time_ms,
            &self.tensor_core,
            &self.kern_start,
            &self.kern_fwd_end,
            &self.kern_end,
            dest.spec(),
        );
        let lane = ExtLane {
            gamma: row.gamma.into(),
            wave: row.wave.into(),
            amp: row.amp.into(),
        };
        let mut ext = self.ext.write().unwrap();
        if ext.len() <= i {
            ext.resize(i + 1, None);
        }
        if ext[i].is_none() {
            ext[i] = Some(lane);
            true
        } else {
            false
        }
    }

    /// The extension lane for `dest`, computing and appending it on
    /// first touch.
    fn ext_lane_or_extend(&self, dest: Device) -> ExtLane {
        if let Some(lane) = self.ext_lane(dest) {
            return lane;
        }
        self.extend_device(dest);
        self.ext_lane(dest).expect("lane appended by extend_device")
    }

    /// One destination's γ/wave lanes, borrowed from the dense tables
    /// when `dest` is inside the snapshot, served from the appended
    /// extension lane (computed once on first touch) when it was
    /// registered later — bit-identical either way. The evaluators
    /// fetch this once and index it per kernel, keeping the hot loop
    /// branch- and lock-free for snapshot devices.
    pub fn device_lanes(&self, dest: Device) -> DeviceLanes<'_> {
        let (nk, ns) = (self.n_kernels(), self.n_shapes());
        let d = dest.index();
        if d < self.n_devices {
            DeviceLanes {
                gamma: Lane::Dense(&self.gamma[d * nk..(d + 1) * nk]),
                wave: Lane::Dense(&self.wave_dest[d * ns..(d + 1) * ns]),
                shape_idx: &self.shape_idx,
            }
        } else {
            let lane = self.ext_lane_or_extend(dest);
            DeviceLanes {
                gamma: Lane::Ext(lane.gamma),
                wave: Lane::Ext(lane.wave),
                shape_idx: &self.shape_idx,
            }
        }
    }

    /// The Daydream AMP factor per op on `dest` (the dense table for
    /// snapshot devices, the appended extension lane otherwise).
    pub fn amp_factors(&self, dest: Device) -> AmpFactors<'_> {
        let d = dest.index();
        let no = self.n_ops();
        if d < self.n_devices {
            AmpFactors::Dense(&self.amp_op_factor[d * no..(d + 1) * no])
        } else {
            AmpFactors::Ext(self.ext_lane_or_extend(dest).amp)
        }
    }

    /// The dense per-device tables, exposed for the persistent store's
    /// encoder (everything else about a record is re-derived from the
    /// trace at load time): `(wave_origin, wave_dest, gamma,
    /// amp_op_factor)`.
    pub(crate) fn lane_tables(&self) -> (&[u64], &[u64], &[f64], &[f64]) {
        (&self.wave_origin, &self.wave_dest, &self.gamma, &self.amp_op_factor)
    }

    pub fn mlp_groups(&self) -> &[MlpGroup] {
        &self.mlp_groups
    }

    /// Measured kernel times on the origin, flat prediction order — the
    /// one per-kernel array the batched sweep reads from the plan.
    pub(crate) fn kernel_times(&self) -> &[f64] {
        &self.time_ms
    }

    /// Fill `scratch` with the dense `kernels × unique-dests` lane
    /// matrices for the batched evaluator. [`EvalScratch::begin`] must
    /// have deduped the destination set first. The layout is transposed
    /// (`[kernel * stride + dest]`, rows lane-padded to the SIMD chunk
    /// width with identity values) so the sweep's innermost destination
    /// loop walks contiguous memory in whole vector chunks.
    pub(crate) fn gather_lanes(&self, eq1: bool, scratch: &mut EvalScratch) {
        let (nk, no, ns) = (self.n_kernels(), self.n_ops(), self.n_shapes());
        let EvalScratch {
            dests,
            stride,
            bw,
            clock,
            gamma_t,
            wave_t,
            waves_d_t,
            waves_o,
            wc,
            p1,
            p2,
            acc,
            mlp_hit,
            fallbacks,
            amp_t,
            n_ops,
            grew,
            ..
        } = scratch;
        let sd = *stride;
        // Pad fills are the identity of each lane (ratio 1, γ 0, wave
        // count 1): pad elements flow through the same vector arithmetic
        // as real destinations, stay finite, and are never read back.
        ensure(bw, sd, 1.0, grew);
        ensure(clock, sd, 1.0, grew);
        ensure(gamma_t, nk * sd, 0.0, grew);
        ensure(wave_t, nk * sd, 1.0, grew);
        if eq1 {
            ensure(waves_d_t, nk * sd, 1.0, grew);
            ensure(waves_o, nk, 0.0, grew);
            for k in 0..nk {
                // The exact `scale_eq1` origin wave count ⌈B/W_o⌉.
                waves_o[k] = self.blocks[k]
                    .div_ceil(self.wave_origin[self.shape_idx[k] as usize])
                    as f64;
            }
        }
        ensure(wc, sd, 1.0, grew);
        ensure(p1, sd, 1.0, grew);
        ensure(p2, sd, 1.0, grew);
        ensure(acc, no * sd, 0.0, grew);
        ensure(mlp_hit, no * sd, false, grew);
        ensure(fallbacks, dests.len(), 0, grew);
        ensure(amp_t, no * sd, 1.0, grew);
        *n_ops = no;

        let origin_spec = self.origin.spec();
        for (di, &dest) in dests.iter().enumerate() {
            let spec = dest.spec();
            bw[di] = origin_spec.achieved_bw_bytes() / spec.achieved_bw_bytes();
            clock[di] = origin_spec.boost_clock_mhz / spec.boost_clock_mhz;
            let d = dest.index();
            let ext;
            let (g_row, w_row): (&[f64], &[u64]) = if d < self.n_devices {
                (
                    &self.gamma[d * nk..(d + 1) * nk],
                    &self.wave_dest[d * ns..(d + 1) * ns],
                )
            } else {
                // Post-snapshot destination: served from the appended
                // extension lane. First touch computes it (same helpers
                // as the dense build — bit-identical); steady-state
                // sweeps just `Arc`-bump it and stay allocation-free.
                ext = self.ext_lane_or_extend(dest);
                (&ext.gamma[..], &ext.wave[..])
            };
            for k in 0..nk {
                let s = self.shape_idx[k] as usize;
                let w_dest = w_row[s];
                gamma_t[k * sd + di] = g_row[k];
                // The exact `ratios_from_parts` wave ratio `W_o/W_d`.
                wave_t[k * sd + di] = self.wave_origin[s] as f64 / w_dest as f64;
                if eq1 {
                    waves_d_t[k * sd + di] = self.blocks[k].div_ceil(w_dest) as f64;
                }
            }
        }
    }

    /// One destination's Daydream AMP factor row — borrowed from the
    /// dense table for snapshot devices, staged from the appended
    /// extension lane into `buf` (reused across sweeps, a straight
    /// copy) for post-snapshot ones.
    pub(crate) fn amp_row<'a>(&'a self, dest: Device, buf: &'a mut Vec<f64>) -> &'a [f64] {
        let d = dest.index();
        let no = self.n_ops();
        if d < self.n_devices {
            &self.amp_op_factor[d * no..(d + 1) * no]
        } else {
            let lane = self.ext_lane_or_extend(dest);
            buf.clear();
            buf.extend_from_slice(&lane.amp);
            buf
        }
    }

    /// Apply the precomputed Daydream AMP transformation (§6.1.2) to an
    /// FP32 prediction of this plan on `pred.dest`, in place.
    /// Bit-identical to [`amp::amp_transform`] over the source trace.
    pub fn apply_amp(&self, pred: &mut PredictedTrace) {
        let factors = self.amp_factors(pred.dest);
        for (o, op) in pred.ops.iter_mut().enumerate() {
            op.time_ms *= factors[o];
        }
    }

    /// A freshly initialized per-op output vector: every op wave-scaled
    /// by default, times zeroed. Shared by the evaluators.
    pub(crate) fn blank_ops(&self) -> Vec<PredictedOp> {
        (0..self.n_ops())
            .map(|o| PredictedOp {
                index: self.op_index[o],
                name: self.op_name[o].clone(),
                short_name: self.op_short_name[o].to_string(),
                time_ms: 0.0,
                method: crate::predict::PredictionMethod::WaveScaling,
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::ALL_DEVICES;
    use crate::opgraph::{EwKind, Op, OpKind};
    use crate::tracker::OperationTracker;

    fn toy_trace(origin: Device) -> Trace {
        let mut g = crate::Graph::new("toy", 16);
        g.push(Op::new(
            "conv",
            OpKind::Conv2d {
                in_ch: 64,
                out_ch: 64,
                kernel: 3,
                stride: 1,
                padding: 1,
                bias: false,
            },
            vec![16, 64, 32, 32],
        ));
        g.push(Op::new("act", OpKind::Elementwise { kind: EwKind::Relu }, vec![16, 64, 32, 32]));
        g.push(Op::new(
            "fc",
            OpKind::Linear {
                in_features: 256,
                out_features: 128,
                bias: true,
            },
            vec![16, 256],
        ));
        OperationTracker::new(origin).track(&g)
    }

    #[test]
    fn flat_arena_covers_every_kernel_in_order() {
        let trace = toy_trace(Device::T4);
        let plan = AnalyzedPlan::build(&trace, &MetricsPolicy::default());
        assert_eq!(plan.n_ops(), trace.ops.len());
        let total_kernels: usize = trace.ops.iter().map(|o| o.fwd.len() + o.bwd.len()).sum();
        assert_eq!(plan.n_kernels(), total_kernels);
        // Ranges partition [0, n_kernels) in op order with the fwd/bwd
        // boundary where the trace puts it.
        let mut cursor = 0usize;
        for (o, t) in trace.ops.iter().enumerate() {
            let r = plan.kernel_range(o);
            assert_eq!(r.start, cursor);
            assert_eq!(plan.fwd_end(o) - r.start, t.fwd.len());
            assert_eq!(r.end - r.start, t.fwd.len() + t.bwd.len());
            for (k, m) in r.clone().zip(t.fwd.iter().chain(&t.bwd)) {
                assert_eq!(plan.kernel_time_ms(k), m.time_ms);
                assert_eq!(plan.kernel_blocks(k), m.kernel.launch.grid_blocks.max(1));
            }
            cursor = r.end;
        }
        assert_eq!(cursor, plan.n_kernels());
    }

    #[test]
    fn wave_sizes_match_the_memo_table_for_every_device() {
        let trace = toy_trace(Device::P4000);
        let plan = AnalyzedPlan::build(&trace, &MetricsPolicy::All);
        let table = WaveTable::global();
        for (o, t) in trace.ops.iter().enumerate() {
            for (k, m) in plan.kernel_range(o).zip(t.fwd.iter().chain(&t.bwd)) {
                assert_eq!(
                    plan.wave_origin(k),
                    table.wave_size(trace.origin.spec(), &m.kernel.launch).max(1)
                );
                for dev in ALL_DEVICES {
                    assert_eq!(
                        plan.wave_dest(k, dev),
                        table.wave_size(dev.spec(), &m.kernel.launch).max(1),
                        "{dev} wave size"
                    );
                }
            }
        }
        assert!(plan.n_shapes() <= plan.n_kernels());
    }

    #[test]
    fn gamma_bakes_in_the_metrics_policy() {
        let trace = toy_trace(Device::V100);
        // Cold cache: every kernel takes the γ = 1 fallback.
        let cold = AnalyzedPlan::build(&trace, &MetricsPolicy::None);
        for dev in ALL_DEVICES {
            for k in 0..cold.n_kernels() {
                assert_eq!(cold.gamma(k, dev), 1.0);
            }
        }
        // Warm cache: γ comes from the roofline for every kernel.
        let warm = AnalyzedPlan::build(&trace, &MetricsPolicy::All);
        let mut non_unit = 0;
        for (o, t) in trace.ops.iter().enumerate() {
            for (k, m) in warm.kernel_range(o).zip(t.fwd.iter().chain(&t.bwd)) {
                for dev in ALL_DEVICES {
                    let expect = roofline::gamma(m.kernel.arith_intensity(), dev.spec());
                    assert_eq!(warm.gamma(k, dev), expect);
                    if expect != 1.0 {
                        non_unit += 1;
                    }
                }
            }
        }
        assert!(non_unit > 0, "a GEMM-bearing trace must have γ < 1 kernels");
    }

    #[test]
    fn mlp_groups_match_trace_features_in_dispatch_order() {
        let trace = toy_trace(Device::T4);
        let plan = AnalyzedPlan::build(&trace, &MetricsPolicy::default());
        // conv + linear ⇒ two groups, BTreeMap (MlpOp) order.
        assert_eq!(plan.mlp_groups().len(), 2);
        assert!(plan.mlp_groups().windows(2).all(|w| w[0].op < w[1].op));
        for group in plan.mlp_groups() {
            assert_eq!(group.slots.len(), group.features.len());
            for (&slot, feat) in group.slots.iter().zip(&group.features) {
                let (op, expect) = trace.ops[slot].op.mlp_features().unwrap();
                assert_eq!(op, group.op);
                assert_eq!(*feat, expect);
            }
        }
    }

    #[test]
    fn lanes_for_late_registered_device_match_a_fresh_plan_and_the_legacy_path() {
        use crate::device::registry::{self as reg, NewDevice};
        use crate::predict::HybridPredictor;

        // A plan compiled *before* a device registration must serve the
        // new device through its computed lanes, bit-identical to a
        // plan whose snapshot includes it — and to the legacy
        // trace-walking reference path.
        let p = HybridPredictor::wave_only();
        let trace = toy_trace(Device::T4);
        let stale = AnalyzedPlan::build(&trace, &p.metrics_policy);
        let d = reg::register(&NewDevice {
            usd_per_hr: Some(0.9),
            ..NewDevice::new("sim-plan-late", 48, 1500.0, 400.0, 12.0, true)
        })
        .unwrap();
        assert!(
            d.index() >= stale.n_devices(),
            "the new device must be outside the stale plan's snapshot"
        );
        let fresh = AnalyzedPlan::build(&trace, &p.metrics_policy);
        assert!(d.index() < fresh.n_devices());

        let lanes = stale.device_lanes(d);
        for k in 0..stale.n_kernels() {
            assert_eq!(stale.gamma(k, d).to_bits(), fresh.gamma(k, d).to_bits());
            assert_eq!(stale.wave_dest(k, d), fresh.wave_dest(k, d));
            assert_eq!(lanes.gamma(k).to_bits(), fresh.gamma(k, d).to_bits());
            assert_eq!(lanes.wave_dest(k), fresh.wave_dest(k, d));
        }
        assert_eq!(stale.amp_factors(d).as_ref(), fresh.amp_factors(d).as_ref());

        let legacy = p.predict(&trace, d);
        for (plan, label) in [(&stale, "stale"), (&fresh, "fresh")] {
            let fast = p.evaluate(plan, d);
            for (a, b) in legacy.ops.iter().zip(&fast.ops) {
                assert_eq!(
                    a.time_ms.to_bits(),
                    b.time_ms.to_bits(),
                    "{label} plan, op {}",
                    a.name
                );
            }
        }
        let amp_stale = p.evaluate_with_precision(&stale, d, Precision::Amp);
        let amp_fresh = p.evaluate_with_precision(&fresh, d, Precision::Amp);
        assert_eq!(
            amp_stale.run_time_ms().to_bits(),
            amp_fresh.run_time_ms().to_bits(),
            "AMP through computed lanes must match the dense path"
        );
    }

    #[test]
    fn parallel_build_is_bit_identical_to_serial() {
        let trace = toy_trace(Device::V100);
        let policy = MetricsPolicy::default();
        let serial = AnalyzedPlan::build(&trace, &policy);
        let pool = WorkerPool::new(4);
        let (parallel, chunks) = AnalyzedPlan::build_parallel(&trace, &policy, &pool);
        // The registry can grow between the two builds (tests run
        // concurrently); chunks = the parallel snapshot's device count.
        assert_eq!(chunks as usize, parallel.n_devices());
        assert!(chunks >= 2);
        assert_eq!(parallel.n_kernels(), serial.n_kernels());
        assert_eq!(parallel.n_shapes(), serial.n_shapes());
        for k in 0..serial.n_kernels() {
            assert_eq!(parallel.wave_origin(k), serial.wave_origin(k));
            for dev in ALL_DEVICES {
                assert_eq!(
                    parallel.gamma(k, dev).to_bits(),
                    serial.gamma(k, dev).to_bits(),
                    "{dev} γ kernel {k}"
                );
                assert_eq!(parallel.wave_dest(k, dev), serial.wave_dest(k, dev));
            }
        }
        for dev in ALL_DEVICES {
            assert_eq!(parallel.amp_factors(dev).as_ref(), serial.amp_factors(dev).as_ref());
        }
    }

    #[test]
    fn extend_device_appends_a_lane_once() {
        use crate::device::registry::{self as reg, NewDevice};

        let trace = toy_trace(Device::T4);
        let plan = AnalyzedPlan::build(&trace, &MetricsPolicy::All);
        let d = reg::register(&NewDevice::new("sim-plan-ext", 40, 1400.0, 350.0, 10.0, true))
            .unwrap();
        assert!(d.index() >= plan.n_devices());
        assert!(!plan.extend_device(Device::T4), "snapshot devices have dense lanes");
        assert!(plan.extend_device(d), "first extension computes the lane");
        assert!(!plan.extend_device(d), "second extension is a no-op");

        let fresh = AnalyzedPlan::build(&trace, &MetricsPolicy::All);
        for k in 0..plan.n_kernels() {
            assert_eq!(plan.gamma(k, d).to_bits(), fresh.gamma(k, d).to_bits());
            assert_eq!(plan.wave_dest(k, d), fresh.wave_dest(k, d));
        }
        assert_eq!(plan.amp_factors(d).as_ref(), fresh.amp_factors(d).as_ref());
    }

    #[test]
    fn restored_lanes_reassemble_bit_identically() {
        let trace = toy_trace(Device::Rtx2070);
        let policy = MetricsPolicy::default();
        let built = AnalyzedPlan::build(&trace, &policy);
        let (wo, wd, g, a) = built.lane_tables();
        let lanes = DenseLanes {
            n_devices: built.n_devices(),
            wave_origin: wo.to_vec(),
            wave_dest: wd.to_vec(),
            gamma: g.to_vec(),
            amp_op_factor: a.to_vec(),
        };
        let restored = AnalyzedPlan::from_parts(&trace, &policy, lanes).unwrap();
        assert_eq!(restored.n_devices(), built.n_devices());
        for k in 0..built.n_kernels() {
            assert_eq!(restored.wave_origin(k), built.wave_origin(k));
            for dev in ALL_DEVICES {
                assert_eq!(restored.gamma(k, dev).to_bits(), built.gamma(k, dev).to_bits());
                assert_eq!(restored.wave_dest(k, dev), built.wave_dest(k, dev));
            }
        }
        // Dimension mismatches are rejected, not silently mis-indexed.
        let bad = DenseLanes {
            n_devices: built.n_devices(),
            wave_origin: Vec::new(),
            wave_dest: wd.to_vec(),
            gamma: g.to_vec(),
            amp_op_factor: a.to_vec(),
        };
        assert!(AnalyzedPlan::from_parts(&trace, &policy, bad).is_err());
    }

    #[test]
    fn eval_scratch_dedups_and_reuses_capacity() {
        let trace = toy_trace(Device::T4);
        let plan = AnalyzedPlan::build(&trace, &MetricsPolicy::All);
        let mut scratch = EvalScratch::new();
        let dests = [
            Device::V100,
            Device::P4000,
            Device::V100,
            Device::P4000,
            Device::V100,
        ];
        scratch.begin(&dests);
        assert_eq!(scratch.n_unique(), 2, "duplicates must collapse");
        assert_eq!(scratch.n_dests(), 5);
        assert_eq!(scratch.slot, vec![0, 1, 0, 1, 0]);
        plan.gather_lanes(true, &mut scratch);
        assert!(scratch.grew(), "first sweep must size the buffers");

        scratch.begin(&dests);
        plan.gather_lanes(true, &mut scratch);
        assert!(!scratch.grew(), "steady state must reuse capacity");

        // A smaller destination set fits in retained capacity too.
        scratch.begin(&dests[..2]);
        plan.gather_lanes(true, &mut scratch);
        assert!(!scratch.grew(), "shrinking sweeps must not reallocate");
    }

    #[test]
    fn gathered_lanes_match_the_scalar_accessors() {
        let trace = toy_trace(Device::P4000);
        let plan = AnalyzedPlan::build(&trace, &MetricsPolicy::All);
        let mut scratch = EvalScratch::new();
        let dests = [Device::V100, Device::T4, Device::V100];
        scratch.begin(&dests);
        plan.gather_lanes(true, &mut scratch);
        assert_eq!(scratch.n_unique(), 2);
        let sd = scratch.stride;
        assert_eq!(sd, crate::util::simdf64::LANES, "2 unique dests pad to one lane chunk");
        let origin = plan.origin.spec();
        for (u, &dest) in scratch.dests.iter().enumerate() {
            let spec = dest.spec();
            assert_eq!(
                scratch.bw[u].to_bits(),
                (origin.achieved_bw_bytes() / spec.achieved_bw_bytes()).to_bits()
            );
            assert_eq!(
                scratch.clock[u].to_bits(),
                (origin.boost_clock_mhz / spec.boost_clock_mhz).to_bits()
            );
            for k in 0..plan.n_kernels() {
                assert_eq!(
                    scratch.gamma_t[k * sd + u].to_bits(),
                    plan.gamma(k, dest).to_bits(),
                    "{dest} γ kernel {k}"
                );
                let (wo, wd) = (plan.wave_origin(k), plan.wave_dest(k, dest));
                assert_eq!(
                    scratch.wave_t[k * sd + u].to_bits(),
                    (wo as f64 / wd as f64).to_bits(),
                    "{dest} wave ratio kernel {k}"
                );
                assert_eq!(
                    scratch.waves_d_t[k * sd + u],
                    plan.kernel_blocks(k).div_ceil(wd) as f64,
                    "{dest} ⌈B/W_d⌉ kernel {k}"
                );
                assert_eq!(
                    scratch.waves_o[k],
                    plan.kernel_blocks(k).div_ceil(wo) as f64,
                    "⌈B/W_o⌉ kernel {k}"
                );
            }
        }
        // Pad lanes hold the documented identity values.
        for u in scratch.n_unique()..sd {
            assert_eq!(scratch.bw[u], 1.0);
            assert_eq!(scratch.clock[u], 1.0);
            for k in 0..plan.n_kernels() {
                assert_eq!(scratch.gamma_t[k * sd + u], 0.0, "pad γ kernel {k}");
                assert_eq!(scratch.wave_t[k * sd + u], 1.0, "pad wave ratio kernel {k}");
                assert_eq!(scratch.waves_d_t[k * sd + u], 1.0, "pad ⌈B/W_d⌉ kernel {k}");
            }
        }
    }

    #[test]
    fn plan_metadata_mirrors_the_trace() {
        let trace = toy_trace(Device::Rtx2070);
        let plan = AnalyzedPlan::build(&trace, &MetricsPolicy::default());
        assert_eq!(plan.model, trace.model);
        assert_eq!(plan.batch_size, trace.batch_size);
        assert_eq!(plan.origin, trace.origin);
        assert_eq!(plan.origin_run_time_ms.to_bits(), trace.run_time_ms().to_bits());
        for (o, t) in trace.ops.iter().enumerate() {
            assert_eq!(plan.op_index(o), t.index);
            assert_eq!(plan.op_name(o), t.op.name);
            assert_eq!(plan.op_short_name(o), t.op.kind.short_name());
        }
    }
}
