//! The compiled-plan IR: everything destination-independent, hoisted.
//!
//! Habitat's core loop (Eq. 1/2, §3.3) scales every kernel of an origin
//! trace onto each destination GPU. The scaling itself is cheap
//! arithmetic — but the naive pipeline re-pays destination-independent
//! work inside the per-destination loop: wave-size lookups through the
//! global [`crate::engine::memo::WaveTable`] mutex, roofline γ selection
//! per kernel per destination, and MLP feature-vector construction per
//! op per destination. When one trace fans out to N GPUs per `rank`
//! RPC, that per-destination cost is the product that multiplies.
//!
//! [`AnalyzedPlan`] is the fix: a flat structure-of-arrays arena built
//! **once** per trace that hoists everything that does not depend on the
//! destination *choice*:
//!
//! * per-kernel launch metadata (grid blocks, measured time, arithmetic
//!   intensity, AMP/tensor-core eligibility) in one flat arena, with
//!   op→kernel index ranges for the forward and backward passes;
//! * wave sizes for **all** `(launch shape, device)` pairs, resolved in
//!   one batched pass at build time — the evaluate loop never touches
//!   the wave table (no lock, no hash);
//! * effective γ per `(kernel, device)` with the metrics-availability
//!   policy (§4.2) baked in at build time;
//! * the Daydream AMP factor per `(op, device)` (§6.1.2);
//! * per-op MLP feature vectors, grouped by MLP family in dispatch
//!   order.
//!
//! The per-destination evaluators
//! ([`crate::predict::HybridPredictor::evaluate`]) are thin loops over
//! these arrays: pure scaling arithmetic, bit-identical to the legacy
//! trace-walking path ([`crate::predict::HybridPredictor::predict`]),
//! which is kept as the reference implementation and pinned against the
//! plan path by the golden regression tests.

use std::collections::{BTreeMap, HashMap};
use std::sync::Arc;

use crate::device::{Device, LaunchConfig, ALL_DEVICES};
use crate::engine::memo::WaveTable;
use crate::lowering::Precision;
use crate::opgraph::MlpOp;
use crate::predict::roofline::{self, MetricsPolicy};
use crate::predict::{amp, PredictedOp, PredictedTrace};
use crate::tracker::Trace;

/// A trace and its compiled plan, produced together by
/// [`crate::tracker::OperationTracker::track_analyzed`] and cached
/// together by the engine. Cloning is two `Arc` bumps.
#[derive(Clone)]
pub struct AnalyzedTrace {
    pub trace: Arc<Trace>,
    pub plan: Arc<AnalyzedPlan>,
}

/// One MLP dispatch group: every op of the trace predicted by the same
/// MLP family, in trace order, with its feature rows prebuilt.
#[derive(Debug, Clone)]
pub struct MlpGroup {
    pub op: MlpOp,
    /// Positions (in plan-op order) of the ops this group overwrites.
    pub slots: Vec<usize>,
    /// One feature row per slot (see [`crate::opgraph::Op::mlp_features`]).
    pub features: Vec<Vec<f64>>,
}

/// The flat, destination-independent compilation of one tracked trace.
///
/// All per-device tables are dense over [`ALL_DEVICES`], indexed by
/// [`Device::index`]; per-kernel arrays are flattened in prediction
/// order (for each op: forward kernels, then backward kernels).
pub struct AnalyzedPlan {
    pub model: String,
    pub batch_size: usize,
    pub origin: Device,
    /// Precision the origin trace was *tracked* at.
    pub precision: Precision,
    /// Measured iteration time on the origin, ms.
    pub origin_run_time_ms: f64,

    // --- per-op arrays (len = n_ops) --------------------------------
    op_index: Vec<usize>,
    op_name: Vec<String>,
    op_short_name: Vec<&'static str>,
    /// Flat-kernel range starts; `kern_start[o]..kern_fwd_end[o]` is the
    /// op's forward pass, `kern_fwd_end[o]..kern_end[o]` its backward.
    kern_start: Vec<u32>,
    kern_fwd_end: Vec<u32>,
    kern_end: Vec<u32>,

    // --- per-kernel arrays (len = n_kernels) ------------------------
    time_ms: Vec<f64>,
    /// Grid blocks (`B` of Eq. 1), clamped to ≥ 1.
    blocks: Vec<u64>,
    /// Index into the deduplicated launch-shape tables.
    shape_idx: Vec<u32>,

    // --- per-shape arrays (len = n_shapes) --------------------------
    /// Wave size on the origin device, clamped to ≥ 1.
    wave_origin: Vec<u64>,
    /// Wave size on every device: `[device.index() * n_shapes + shape]`.
    wave_dest: Vec<u64>,

    // --- per-(device, kernel) / per-(device, op) tables -------------
    /// Effective γ with the metrics policy baked in (γ = 1 fallback for
    /// unprofiled kernels): `[device.index() * n_kernels + kernel]`.
    gamma: Vec<f64>,
    /// Daydream AMP factor per op: `[device.index() * n_ops + op]`.
    amp_op_factor: Vec<f64>,

    // --- MLP dispatch -----------------------------------------------
    mlp_groups: Vec<MlpGroup>,
}

impl AnalyzedPlan {
    /// Compile a tracked trace into a plan. `policy` is the metrics-
    /// availability policy of the predictor that will evaluate the plan
    /// (γ selection is baked in here, so the plan must be rebuilt if the
    /// policy changes).
    ///
    /// This is the one place the pipeline touches the shared
    /// [`WaveTable`]: wave sizes for every `(launch shape, device)` pair
    /// are resolved in a single batched pass.
    pub fn build(trace: &Trace, policy: &MetricsPolicy) -> AnalyzedPlan {
        let n_ops = trace.ops.len();
        let profiled_set = policy.profiled_kernels(trace);

        let mut op_index = Vec::with_capacity(n_ops);
        let mut op_name = Vec::with_capacity(n_ops);
        let mut op_short_name = Vec::with_capacity(n_ops);
        let mut kern_start = Vec::with_capacity(n_ops);
        let mut kern_fwd_end = Vec::with_capacity(n_ops);
        let mut kern_end = Vec::with_capacity(n_ops);

        let mut time_ms = Vec::new();
        let mut blocks = Vec::new();
        let mut shape_idx: Vec<u32> = Vec::new();
        let mut profiled: Vec<bool> = Vec::new();
        let mut intensity: Vec<f64> = Vec::new();
        let mut tensor_core: Vec<bool> = Vec::new();

        // Launch-shape dedup: wave sizes depend only on this projection
        // of the launch configuration (grid size excluded).
        let mut shape_of: HashMap<(u32, u32, u32), u32> = HashMap::new();
        let mut shapes: Vec<LaunchConfig> = Vec::new();

        let mut mlp_items: BTreeMap<MlpOp, (Vec<usize>, Vec<Vec<f64>>)> = BTreeMap::new();

        for (slot, t) in trace.ops.iter().enumerate() {
            op_index.push(t.index);
            op_name.push(t.op.name.clone());
            op_short_name.push(t.op.kind.short_name());
            kern_start.push(time_ms.len() as u32);
            for (pass_idx, pass) in [&t.fwd, &t.bwd].into_iter().enumerate() {
                for m in pass {
                    let launch = &m.kernel.launch;
                    let key = (
                        launch.threads_per_block,
                        launch.regs_per_thread,
                        launch.smem_per_block,
                    );
                    let si = *shape_of.entry(key).or_insert_with(|| {
                        shapes.push(*launch);
                        (shapes.len() - 1) as u32
                    });
                    time_ms.push(m.time_ms);
                    blocks.push(launch.grid_blocks.max(1));
                    shape_idx.push(si);
                    profiled.push(
                        profiled_set
                            .as_ref()
                            .map_or(true, |set| set.contains(&roofline::cache_key(&m.kernel))),
                    );
                    intensity.push(m.kernel.arith_intensity());
                    tensor_core.push(m.kernel.tensor_core_eligible);
                }
                if pass_idx == 0 {
                    kern_fwd_end.push(time_ms.len() as u32);
                }
            }
            kern_end.push(time_ms.len() as u32);

            if let Some((mlp_op, features)) = t.op.mlp_features() {
                let entry = mlp_items.entry(mlp_op).or_default();
                entry.0.push(slot);
                entry.1.push(features);
            }
        }

        let n_kernels = time_ms.len();
        let n_shapes = shapes.len();
        let n_devices = ALL_DEVICES.len();

        // Batched wave-size resolution: every (shape, device) pair, one
        // pass, through the shared memo table (so the simulator and any
        // concurrent engine still benefit from the same entries).
        let table = WaveTable::global();
        let origin_spec = trace.origin.spec();
        let wave_origin: Vec<u64> = shapes
            .iter()
            .map(|s| table.wave_size(origin_spec, s).max(1))
            .collect();
        let mut wave_dest = Vec::with_capacity(n_devices * n_shapes);
        for dev in ALL_DEVICES {
            let spec = dev.spec();
            for s in &shapes {
                wave_dest.push(table.wave_size(spec, s).max(1));
            }
        }

        // Per-device tables, one roofline pass each: the raw γ per
        // kernel feeds both the policy-masked γ table (γ = 1 fallback
        // for unprofiled kernels — identical to the legacy
        // per-destination selection) and the Daydream AMP factor per op
        // (the time-weighted mean of per-kernel AMP factors, exactly as
        // `predict::amp::amp_transform` computes it — the AMP transform
        // always uses the raw γ, never the fallback).
        let mut gamma = Vec::with_capacity(n_devices * n_kernels);
        let mut amp_op_factor = Vec::with_capacity(n_devices * n_ops);
        let mut raw_gamma = vec![0.0f64; n_kernels];
        for dev in ALL_DEVICES {
            let spec = dev.spec();
            for k in 0..n_kernels {
                let g = roofline::gamma(intensity[k], spec);
                raw_gamma[k] = g;
                gamma.push(if profiled[k] { g } else { 1.0 });
            }
            for o in 0..n_ops {
                let (start, mid, end) = (
                    kern_start[o] as usize,
                    kern_fwd_end[o] as usize,
                    kern_end[o] as usize,
                );
                let fwd_ms: f64 = time_ms[start..mid].iter().sum();
                let bwd_ms: f64 = time_ms[mid..end].iter().sum();
                let total = fwd_ms + bwd_ms;
                if total <= 0.0 {
                    amp_op_factor.push(1.0);
                    continue;
                }
                let weighted: f64 = (start..end)
                    .map(|k| amp::amp_factor(raw_gamma[k], tensor_core[k], spec) * time_ms[k])
                    .sum();
                amp_op_factor.push(weighted / total);
            }
        }

        let mlp_groups = mlp_items
            .into_iter()
            .map(|(op, (slots, features))| MlpGroup { op, slots, features })
            .collect();

        AnalyzedPlan {
            model: trace.model.clone(),
            batch_size: trace.batch_size,
            origin: trace.origin,
            precision: trace.precision,
            origin_run_time_ms: trace.run_time_ms(),
            op_index,
            op_name,
            op_short_name,
            kern_start,
            kern_fwd_end,
            kern_end,
            time_ms,
            blocks,
            shape_idx,
            wave_origin,
            wave_dest,
            gamma,
            amp_op_factor,
            mlp_groups,
        }
    }

    pub fn n_ops(&self) -> usize {
        self.op_index.len()
    }

    pub fn n_kernels(&self) -> usize {
        self.time_ms.len()
    }

    pub fn n_shapes(&self) -> usize {
        self.wave_origin.len()
    }

    pub fn op_index(&self, op: usize) -> usize {
        self.op_index[op]
    }

    pub fn op_name(&self, op: usize) -> &str {
        &self.op_name[op]
    }

    pub fn op_short_name(&self, op: usize) -> &'static str {
        self.op_short_name[op]
    }

    /// The op's flat kernel range (forward followed by backward).
    pub fn kernel_range(&self, op: usize) -> std::ops::Range<usize> {
        self.kern_start[op] as usize..self.kern_end[op] as usize
    }

    /// The op's forward/backward boundary within [`Self::kernel_range`].
    pub fn fwd_end(&self, op: usize) -> usize {
        self.kern_fwd_end[op] as usize
    }

    pub fn kernel_time_ms(&self, kernel: usize) -> f64 {
        self.time_ms[kernel]
    }

    pub fn kernel_blocks(&self, kernel: usize) -> u64 {
        self.blocks[kernel]
    }

    /// Wave size of a kernel's launch shape on the origin device.
    pub fn wave_origin(&self, kernel: usize) -> u64 {
        self.wave_origin[self.shape_idx[kernel] as usize]
    }

    /// Wave size of a kernel's launch shape on `dest` (precomputed).
    pub fn wave_dest(&self, kernel: usize, dest: Device) -> u64 {
        self.wave_dest[dest.index() * self.n_shapes() + self.shape_idx[kernel] as usize]
    }

    /// Effective γ of a kernel on `dest` (policy fallback baked in).
    pub fn gamma(&self, kernel: usize, dest: Device) -> f64 {
        self.gamma[dest.index() * self.n_kernels() + kernel]
    }

    pub fn mlp_groups(&self) -> &[MlpGroup] {
        &self.mlp_groups
    }

    /// Apply the precomputed Daydream AMP transformation (§6.1.2) to an
    /// FP32 prediction of this plan on `pred.dest`, in place.
    /// Bit-identical to [`amp::amp_transform`] over the source trace.
    pub fn apply_amp(&self, pred: &mut PredictedTrace) {
        let base = pred.dest.index() * self.n_ops();
        for (o, op) in pred.ops.iter_mut().enumerate() {
            op.time_ms *= self.amp_op_factor[base + o];
        }
    }

    /// A freshly initialized per-op output vector: every op wave-scaled
    /// by default, times zeroed. Shared by the evaluators.
    pub(crate) fn blank_ops(&self) -> Vec<PredictedOp> {
        (0..self.n_ops())
            .map(|o| PredictedOp {
                index: self.op_index[o],
                name: self.op_name[o].clone(),
                short_name: self.op_short_name[o].to_string(),
                time_ms: 0.0,
                method: crate::predict::PredictionMethod::WaveScaling,
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::opgraph::{EwKind, Op, OpKind};
    use crate::tracker::OperationTracker;

    fn toy_trace(origin: Device) -> Trace {
        let mut g = crate::Graph::new("toy", 16);
        g.push(Op::new(
            "conv",
            OpKind::Conv2d {
                in_ch: 64,
                out_ch: 64,
                kernel: 3,
                stride: 1,
                padding: 1,
                bias: false,
            },
            vec![16, 64, 32, 32],
        ));
        g.push(Op::new("act", OpKind::Elementwise { kind: EwKind::Relu }, vec![16, 64, 32, 32]));
        g.push(Op::new(
            "fc",
            OpKind::Linear {
                in_features: 256,
                out_features: 128,
                bias: true,
            },
            vec![16, 256],
        ));
        OperationTracker::new(origin).track(&g)
    }

    #[test]
    fn flat_arena_covers_every_kernel_in_order() {
        let trace = toy_trace(Device::T4);
        let plan = AnalyzedPlan::build(&trace, &MetricsPolicy::default());
        assert_eq!(plan.n_ops(), trace.ops.len());
        let total_kernels: usize = trace.ops.iter().map(|o| o.fwd.len() + o.bwd.len()).sum();
        assert_eq!(plan.n_kernels(), total_kernels);
        // Ranges partition [0, n_kernels) in op order with the fwd/bwd
        // boundary where the trace puts it.
        let mut cursor = 0usize;
        for (o, t) in trace.ops.iter().enumerate() {
            let r = plan.kernel_range(o);
            assert_eq!(r.start, cursor);
            assert_eq!(plan.fwd_end(o) - r.start, t.fwd.len());
            assert_eq!(r.end - r.start, t.fwd.len() + t.bwd.len());
            for (k, m) in r.clone().zip(t.fwd.iter().chain(&t.bwd)) {
                assert_eq!(plan.kernel_time_ms(k), m.time_ms);
                assert_eq!(plan.kernel_blocks(k), m.kernel.launch.grid_blocks.max(1));
            }
            cursor = r.end;
        }
        assert_eq!(cursor, plan.n_kernels());
    }

    #[test]
    fn wave_sizes_match_the_memo_table_for_every_device() {
        let trace = toy_trace(Device::P4000);
        let plan = AnalyzedPlan::build(&trace, &MetricsPolicy::All);
        let table = WaveTable::global();
        for (o, t) in trace.ops.iter().enumerate() {
            for (k, m) in plan.kernel_range(o).zip(t.fwd.iter().chain(&t.bwd)) {
                assert_eq!(
                    plan.wave_origin(k),
                    table.wave_size(trace.origin.spec(), &m.kernel.launch).max(1)
                );
                for dev in ALL_DEVICES {
                    assert_eq!(
                        plan.wave_dest(k, dev),
                        table.wave_size(dev.spec(), &m.kernel.launch).max(1),
                        "{dev} wave size"
                    );
                }
            }
        }
        assert!(plan.n_shapes() <= plan.n_kernels());
    }

    #[test]
    fn gamma_bakes_in_the_metrics_policy() {
        let trace = toy_trace(Device::V100);
        // Cold cache: every kernel takes the γ = 1 fallback.
        let cold = AnalyzedPlan::build(&trace, &MetricsPolicy::None);
        for dev in ALL_DEVICES {
            for k in 0..cold.n_kernels() {
                assert_eq!(cold.gamma(k, dev), 1.0);
            }
        }
        // Warm cache: γ comes from the roofline for every kernel.
        let warm = AnalyzedPlan::build(&trace, &MetricsPolicy::All);
        let mut non_unit = 0;
        for (o, t) in trace.ops.iter().enumerate() {
            for (k, m) in warm.kernel_range(o).zip(t.fwd.iter().chain(&t.bwd)) {
                for dev in ALL_DEVICES {
                    let expect = roofline::gamma(m.kernel.arith_intensity(), dev.spec());
                    assert_eq!(warm.gamma(k, dev), expect);
                    if expect != 1.0 {
                        non_unit += 1;
                    }
                }
            }
        }
        assert!(non_unit > 0, "a GEMM-bearing trace must have γ < 1 kernels");
    }

    #[test]
    fn mlp_groups_match_trace_features_in_dispatch_order() {
        let trace = toy_trace(Device::T4);
        let plan = AnalyzedPlan::build(&trace, &MetricsPolicy::default());
        // conv + linear ⇒ two groups, BTreeMap (MlpOp) order.
        assert_eq!(plan.mlp_groups().len(), 2);
        assert!(plan.mlp_groups().windows(2).all(|w| w[0].op < w[1].op));
        for group in plan.mlp_groups() {
            assert_eq!(group.slots.len(), group.features.len());
            for (&slot, feat) in group.slots.iter().zip(&group.features) {
                let (op, expect) = trace.ops[slot].op.mlp_features().unwrap();
                assert_eq!(op, group.op);
                assert_eq!(*feat, expect);
            }
        }
    }

    #[test]
    fn plan_metadata_mirrors_the_trace() {
        let trace = toy_trace(Device::Rtx2070);
        let plan = AnalyzedPlan::build(&trace, &MetricsPolicy::default());
        assert_eq!(plan.model, trace.model);
        assert_eq!(plan.batch_size, trace.batch_size);
        assert_eq!(plan.origin, trace.origin);
        assert_eq!(plan.origin_run_time_ms.to_bits(), trace.run_time_ms().to_bits());
        for (o, t) in trace.ops.iter().enumerate() {
            assert_eq!(plan.op_index(o), t.index);
            assert_eq!(plan.op_name(o), t.op.name);
            assert_eq!(plan.op_short_name(o), t.op.kind.short_name());
        }
    }
}
