//! MLP training-data generation (paper §4.3.1–§4.3.2).
//!
//! The paper measures kernel-varying operations at randomly sampled input
//! configurations on all six GPUs (same seed everywhere ⇒ same configs),
//! then joins entries per configuration across GPUs and attaches four GPU
//! hardware features. This module reproduces that pipeline with the
//! simulator as the measurement substrate, writing one CSV per operation:
//!
//! ```text
//! <op features...>, gpu_mem_gib, gpu_bw_gbps, gpu_sms, gpu_tflops, time_ms
//! ```
//!
//! where `time_ms` is the forward + backward execution time. Feature
//! layouts match [`crate::opgraph::Op::mlp_features`] and the GPU feature
//! block matches [`gpu_features`] — the Python training code and the Rust
//! PJRT runtime both rely on this exact ordering.

use crate::device::{registry, Device};
use crate::lowering::{lower, Pass, Precision};
use crate::opgraph::{MlpOp, Op, OpKind};
use crate::sim::Simulator;
use crate::util::csv::CsvWriter;
use crate::util::Rng;
use crate::Result;

/// The four GPU hardware features attached to every sample (§4.3.2):
/// memory capacity, memory bandwidth, SM count, peak FLOPS.
pub fn gpu_features(device: Device) -> [f64; 4] {
    let s = device.spec();
    [
        s.mem_gib,
        s.achieved_mem_bw_gbps,
        s.sms as f64,
        s.peak_fp32_tflops,
    ]
}

/// CSV header for an operation's dataset.
pub fn header(op: MlpOp) -> Vec<&'static str> {
    let mut h: Vec<&'static str> = match op {
        MlpOp::Conv2d => vec!["batch", "in_ch", "out_ch", "kernel", "stride", "padding", "image"],
        MlpOp::Lstm => vec!["batch", "input", "hidden", "seq", "layers", "bidir", "bias"],
        MlpOp::Bmm => vec!["b", "l", "m", "r"],
        MlpOp::Linear => vec!["rows", "in_features", "out_features", "bias"],
    };
    h.extend(["gpu_mem_gib", "gpu_bw_gbps", "gpu_sms", "gpu_tflops", "time_ms"]);
    h
}

/// Rough per-GPU memory-feasibility check: the paper discards sampled
/// configurations that run out of memory. 3× covers activations, grads,
/// and optimizer/workspace.
fn fits_in_memory(activation_elems: f64, weight_elems: f64, mem_gib: f64) -> bool {
    (activation_elems + weight_elems) * 4.0 * 3.0 < mem_gib * 0.9 * (1u64 << 30) as f64
}

/// Sample one conv2d configuration (§4.3.1 ranges, extended: batch→128, image→320 to cover the paper's own eval workloads). Returns `None` for
/// invalid or OOM configurations, which the caller resamples.
pub fn sample_conv2d(rng: &mut Rng) -> Option<Op> {
    let batch = rng.int_range(1, 128) as usize;
    let in_ch = rng.log_int_range(3, 2048) as usize;
    let out_ch = rng.log_int_range(16, 2048) as usize;
    // Kernel size and stride are sampled with torchvision-informed weights
    // (the paper selected its ranges "by surveying the convolutional
    // neural networks included in torchvision"): 3×3 stride-1 dominates
    // real CNNs, and it is also exactly the algorithm-selection boundary
    // (Winograd vs implicit GEMM) the MLP must learn per architecture.
    let kernel = *rng.choose(&[1usize, 1, 1, 3, 3, 3, 3, 5, 5, 7, 9, 11]);
    let padding = rng.int_range(0, 3) as usize;
    let stride = *rng.choose(&[1usize, 1, 1, 2, 2, 3, 4]);
    let image = rng.log_int_range(1, 320) as usize;
    let bias = rng.bool();
    // Invalid: window larger than padded image.
    if kernel > image + 2 * padding {
        return None;
    }
    let op = Op::new(
        "sample",
        OpKind::Conv2d {
            in_ch,
            out_ch,
            kernel,
            stride,
            padding,
            bias,
        },
        vec![batch, in_ch, image, image],
    );
    let out = crate::opgraph::shape::conv_out(image, kernel, stride, padding);
    let act = (batch * in_ch * image * image + batch * out_ch * out * out) as f64;
    let w = (in_ch * out_ch * kernel * kernel) as f64;
    fits_in_memory(act, w, 8.0).then_some(op)
}

/// Sample one LSTM configuration.
pub fn sample_lstm(rng: &mut Rng) -> Option<Op> {
    let batch = rng.int_range(1, 128) as usize;
    let input = rng.log_int_range(1, 1280) as usize;
    let hidden = rng.log_int_range(1, 1280) as usize;
    let seq = rng.int_range(1, 64) as usize;
    let layers = rng.int_range(1, 6) as usize;
    let bidirectional = rng.bool();
    let bias = rng.bool();
    let op = Op::new(
        "sample",
        OpKind::Lstm {
            input,
            hidden,
            layers,
            seq,
            bidirectional,
            bias,
        },
        vec![seq, batch, input],
    );
    let dirs = if bidirectional { 2 } else { 1 };
    let act = (seq * batch * (input + layers * hidden * dirs)) as f64;
    let w = op.kind.parameter_count() as f64;
    fits_in_memory(act, w, 8.0).then_some(op)
}

/// Sample one batched-matmul configuration.
pub fn sample_bmm(rng: &mut Rng) -> Option<Op> {
    let b = rng.log_int_range(1, 1024) as usize;
    let l = rng.log_int_range(1, 1024) as usize;
    let m = rng.log_int_range(1, 1024) as usize;
    let r = rng.log_int_range(1, 1024) as usize;
    let op = Op::new(
        "sample",
        OpKind::BatchedMatmul { b, l, m, r },
        vec![b, l, m],
    );
    let act = (b * (l * m + m * r + l * r)) as f64;
    fits_in_memory(act, 0.0, 8.0).then_some(op)
}

/// Sample one linear-layer configuration.
pub fn sample_linear(rng: &mut Rng) -> Option<Op> {
    let rows = rng.int_range(1, 4096) as usize;
    let in_features = rng.log_int_range(1, 32_768) as usize;
    let out_features = rng.log_int_range(1, 32_768) as usize;
    let bias = rng.bool();
    let op = Op::new(
        "sample",
        OpKind::Linear {
            in_features,
            out_features,
            bias,
        },
        vec![rows, in_features],
    );
    let act = (rows * (in_features + out_features)) as f64;
    let w = (in_features * out_features) as f64;
    fits_in_memory(act, w, 8.0).then_some(op)
}

/// Sample a valid configuration for an op family (resampling rejects).
pub fn sample(op: MlpOp, rng: &mut Rng) -> Op {
    loop {
        let candidate = match op {
            MlpOp::Conv2d => sample_conv2d(rng),
            MlpOp::Lstm => sample_lstm(rng),
            MlpOp::Bmm => sample_bmm(rng),
            MlpOp::Linear => sample_linear(rng),
        };
        if let Some(op) = candidate {
            return op;
        }
    }
}

/// Measure one op's forward+backward time on one device (the per-GPU
/// measurement of §4.3.1). A fresh salt per (config, device) mimics
/// independent measurement runs.
pub fn measure(op: &Op, device: Device, sim: &Simulator) -> f64 {
    let spec = device.spec();
    let fwd = lower(op, spec.arch, Precision::Fp32, Pass::Forward);
    let bwd = lower(op, spec.arch, Precision::Fp32, Pass::Backward);
    sim.kernels_time_ms(spec, &fwd, Precision::Fp32)
        + sim.kernels_time_ms(spec, &bwd, Precision::Fp32)
}

/// Generate the dataset for one op family: `configs` sampled
/// configurations × the given GPUs, written to `<out_dir>/<op>.csv`.
/// The device set is a parameter so runtime-registered GPUs (see
/// [`registry`]) can be included — or excluded for a paper-exact
/// six-GPU dataset ([`crate::device::ALL_DEVICES`]).
pub fn generate(
    op: MlpOp,
    out_dir: &str,
    configs: usize,
    seed: u64,
    devices: &[Device],
) -> Result<usize> {
    anyhow::ensure!(!devices.is_empty(), "dataset generation needs at least one device");
    let mut rng = Rng::new(seed ^ crate::util::rng::hash_str(op.id()));
    let path = format!("{out_dir}/{}.csv", op.id());
    let mut w = CsvWriter::create(&path, &header(op))?;
    let mut rows = 0usize;
    for i in 0..configs {
        let sample_op = sample(op, &mut rng);
        let (mlp_op, features) = sample_op.mlp_features().expect("sampled op is kernel-varying");
        debug_assert_eq!(mlp_op, op);
        // Per-config measurement salt (same across devices, like the
        // paper's same-seed cross-GPU sampling).
        let sim = Simulator::new(crate::sim::SimConfig {
            salt: i as u64,
            ..Default::default()
        });
        for &device in devices {
            let time_ms = measure(&sample_op, device, &sim);
            let mut row = features.clone();
            row.extend(gpu_features(device));
            row.push(time_ms);
            w.row_f64(&row)?;
            rows += 1;
        }
    }
    w.finish()?;
    Ok(rows)
}

/// Generate all four datasets (the `habitat dataset` subcommand) over
/// every device in the registry — runtime registrations included, so a
/// `register_device`d GPU contributes MLP training samples too.
pub fn generate_all(out_dir: &str, configs: usize, seed: u64) -> Result<()> {
    let devices = registry::all_devices();
    for op in MlpOp::ALL {
        let rows = generate(op, out_dir, configs, seed, &devices)?;
        println!(
            "{}: {} configs × {} GPUs = {} rows → {out_dir}/{}.csv",
            op.id(),
            configs,
            devices.len(),
            rows,
            op.id()
        );
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn samples_respect_paper_ranges() {
        let mut rng = Rng::new(7);
        for _ in 0..200 {
            let op = sample(MlpOp::Conv2d, &mut rng);
            if let OpKind::Conv2d {
                in_ch,
                out_ch,
                kernel,
                stride,
                padding,
                ..
            } = op.kind
            {
                assert!((3..=2048).contains(&in_ch));
                assert!((16..=2048).contains(&out_ch));
                assert!((1..=11).contains(&kernel));
                assert!((1..=4).contains(&stride));
                assert!(padding <= 3);
                assert!(kernel <= op.input[2] + 2 * padding);
            } else {
                panic!("not a conv");
            }
        }
    }

    #[test]
    fn sampling_is_deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..50 {
            let x = sample(MlpOp::Bmm, &mut a);
            let y = sample(MlpOp::Bmm, &mut b);
            assert_eq!(format!("{:?}", x.kind), format!("{:?}", y.kind));
        }
    }

    #[test]
    fn measurement_positive_and_device_dependent() {
        let mut rng = Rng::new(3);
        let sim = Simulator::noiseless();
        let op = sample(MlpOp::Linear, &mut rng);
        let t4 = measure(&op, Device::T4, &sim);
        let v100 = measure(&op, Device::V100, &sim);
        assert!(t4 > 0.0 && v100 > 0.0);
        assert_ne!(t4, v100);
    }

    #[test]
    fn generate_writes_joined_rows() {
        let dir = std::env::temp_dir().join("habitat_ds_test");
        let dir_s = dir.to_str().unwrap();
        let rows = generate(MlpOp::Bmm, dir_s, 10, 1, &crate::device::ALL_DEVICES).unwrap();
        assert_eq!(rows, 60);
        let (header_row, data) =
            crate::util::csv::read_numeric(format!("{dir_s}/bmm.csv")).unwrap();
        assert_eq!(header_row.len(), 4 + 4 + 1);
        assert_eq!(data.len(), 60);
        // Same config appears for all six GPUs consecutively.
        for gpu_rows in data.chunks(6) {
            for r in gpu_rows {
                assert_eq!(r[..4], gpu_rows[0][..4]);
                assert!(r[8] > 0.0);
            }
        }
    }

    #[test]
    fn header_matches_feature_count() {
        for op in MlpOp::ALL {
            assert_eq!(header(op).len(), op.feature_count() + 5);
        }
    }
}
