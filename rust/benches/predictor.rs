//! Benchmarks for the prediction hot path: tracking, wave scaling, the
//! plan-build vs per-destination-evaluate split, the engine's
//! cached/fan-out paths, and the full hybrid predictor (when artifacts
//! are available).

use habitat::device::{Device, ALL_DEVICES};
use habitat::engine::PredictionEngine;
use habitat::plan::{AnalyzedPlan, EvalScratch};
use habitat::predict::{HybridPredictor, MetricsPolicy};
use habitat::tracker::OperationTracker;
use habitat::util::bench::bench;
use habitat::Precision;

fn main() {
    println!("== predictor benches ==");
    for model in habitat::models::MODEL_NAMES {
        let graph = habitat::models::by_name(model, 32).unwrap();
        bench(&format!("track/{model}/bs32"), || {
            OperationTracker::new(Device::Rtx2070).track(&graph).run_time_ms()
        });
    }

    let graph = habitat::models::resnet50(32);
    let trace = OperationTracker::new(Device::Rtx2070).track(&graph);

    let wave = HybridPredictor::wave_only();
    bench("predict/wave_only/resnet50", || {
        wave.predict(&trace, Device::V100).run_time_ms()
    });
    let warm = HybridPredictor::wave_only().with_metrics_policy(MetricsPolicy::All);
    bench("predict/wave_only_warm_cache/resnet50", || {
        warm.predict(&trace, Device::V100).run_time_ms()
    });
    let eq1 = HybridPredictor::wave_only().with_eq1(true);
    bench("predict/wave_only_eq1/resnet50", || {
        eq1.predict(&trace, Device::V100).run_time_ms()
    });

    // --- plan: one-time build vs per-destination evaluate ---------------
    // The refactor's claim: analysis (wave-size batching, γ resolution,
    // feature prebuild) is paid once per trace; each destination is pure
    // scaling arithmetic. Compare one evaluate against the legacy
    // trace-walking predict, and a 60-destination fan-out against 60
    // legacy walks.
    let plan = AnalyzedPlan::build(&trace, &wave.metrics_policy);
    bench("plan/build/resnet50", || {
        AnalyzedPlan::build(&trace, &wave.metrics_policy).n_kernels()
    });
    bench("plan/evaluate/resnet50_to_v100", || {
        wave.evaluate(&plan, Device::V100).run_time_ms()
    });
    bench("predict/legacy_trace_walk/resnet50_to_v100", || {
        wave.predict(&trace, Device::V100).run_time_ms()
    });
    let many_dests: Vec<Device> = ALL_DEVICES.iter().copied().cycle().take(60).collect();
    bench("plan/evaluate_60_dests/resnet50", || {
        many_dests
            .iter()
            .map(|d| wave.evaluate(&plan, *d).run_time_ms())
            .sum::<f64>()
    });
    bench("legacy/trace_walk_60_dests/resnet50", || {
        many_dests
            .iter()
            .map(|d| wave.predict(&trace, *d).run_time_ms())
            .sum::<f64>()
    });

    // --- plan: kernel-major batched evaluation ---------------------------
    // One sweep over the plan's kernel arrays fills every destination at
    // once. `evaluate_batch_60_dests` is the headline comparison against
    // `plan/evaluate_60_dests` above (60 scalar calls); the `sweep`
    // variant reuses one scratch arena across iterations, so it also
    // shows the zero-steady-state-allocation regime the serving path
    // runs in (materialization of owned `PredictedTrace`s excluded).
    bench("plan/evaluate_batch_all_dests/resnet50", || {
        wave.evaluate_batch(&plan, &ALL_DEVICES, Precision::Fp32)
            .iter()
            .map(|p| p.run_time_ms())
            .sum::<f64>()
    });
    bench("plan/evaluate_batch_60_dests/resnet50", || {
        wave.evaluate_batch(&plan, &many_dests, Precision::Fp32)
            .iter()
            .map(|p| p.run_time_ms())
            .sum::<f64>()
    });
    let mut sweep_scratch = EvalScratch::new();
    bench("plan/evaluate_batch_sweep_60_dests/resnet50", || {
        wave.evaluate_batch_times(&plan, &many_dests, Precision::Fp32, &mut sweep_scratch);
        (0..many_dests.len())
            .map(|i| sweep_scratch.run_time_ms(i))
            .sum::<f64>()
    });

    // --- plan: SIMD lanes vs the per-destination scalar path -------------
    // The vectorization claim as one gated ratio: `plan/evaluate_60_dests`
    // above is the per-destination scalar path (60 independent evaluate
    // calls); this is the identical workload through the lane-vectorized
    // kernel-major sweep with a warm scratch arena. bench_to_json.py
    // emits their ratio as `scalar_vs_simd_sweep` (CI gates it ≥ 1.5×).
    println!("(simd backend: {})", habitat::util::simdf64::backend());
    let mut simd_scratch = EvalScratch::new();
    bench("plan/evaluate_batch_simd_vs_scalar", || {
        wave.evaluate_batch_times(&plan, &many_dests, Precision::Fp32, &mut simd_scratch);
        (0..many_dests.len())
            .map(|i| simd_scratch.run_time_ms(i))
            .sum::<f64>()
    });

    // --- engine: cold (tracking pipeline every time) vs cached ----------
    let engine = PredictionEngine::wave_only();
    bench("engine/predict_cold/resnet50", || {
        engine.clear_trace_cache();
        engine
            .predict("resnet50", 32, Device::Rtx2070, Device::V100, Precision::Fp32)
            .unwrap()
            .pred
            .run_time_ms()
    });
    bench("engine/predict_cached/resnet50", || {
        engine
            .predict("resnet50", 32, Device::Rtx2070, Device::V100, Precision::Fp32)
            .unwrap()
            .pred
            .run_time_ms()
    });

    // --- engine: single destination vs all-destination fan-out ----------
    let cached = engine.analyzed("resnet50", 32, Device::Rtx2070).unwrap();
    bench("engine/single_dest/resnet50", || {
        engine.evaluate(&cached.plan, Device::V100, Precision::Fp32).run_time_ms()
    });
    bench("engine/fan_out_all_dests/resnet50", || {
        engine
            .fan_out(&cached.plan, &ALL_DEVICES, Precision::Fp32)
            .iter()
            .map(|p| p.run_time_ms())
            .sum::<f64>()
    });
    bench("engine/sequential_all_dests/resnet50", || {
        ALL_DEVICES
            .iter()
            .map(|d| engine.evaluate(&cached.plan, *d, Precision::Fp32).run_time_ms())
            .sum::<f64>()
    });
    bench("engine/fan_out_60_dests/resnet50", || {
        engine.fan_out(&cached.plan, &many_dests, Precision::Fp32).len()
    });
    bench("engine/evaluate_batch_60_dests/resnet50", || {
        // The fan-out fast path without chunking: one thread-local
        // scratch arena, one kernel-major sweep.
        engine
            .evaluate_batch(&cached.plan, &many_dests, Precision::Fp32)
            .len()
    });
    bench("engine/rank_all_dests/resnet50", || {
        engine
            .rank("resnet50", 32, Device::Rtx2070, &ALL_DEVICES, Precision::Fp32)
            .unwrap()
            .entries
            .len()
    });

    // --- engine: one-call multi-trace sweep over the zoo -----------------
    // Five models × 60 destinations as ONE work-claimed job set
    // (`evaluate_many_times`) — the path `rank_many`, the throughput
    // matrices, and `predict_cluster_many` all ride. Jobs and the
    // `SweepTimes` arena are built once outside the closure, so steady
    // state is the zero-allocation serving regime.
    let zoo_plans: Vec<_> = habitat::models::MODEL_NAMES
        .iter()
        .map(|m| engine.analyzed(m, 32, Device::Rtx2070).unwrap())
        .collect();
    let zoo_jobs: Vec<habitat::engine::SweepJob<'_>> = zoo_plans
        .iter()
        .map(|a| habitat::engine::SweepJob {
            plan: std::sync::Arc::clone(&a.plan),
            dests: &many_dests,
            precision: Precision::Fp32,
        })
        .collect();
    let mut zoo_times = habitat::engine::SweepTimes::new();
    bench("engine/evaluate_many_zoo", || {
        engine.evaluate_many_times(&zoo_jobs, &mut zoo_times);
        (0..zoo_jobs.len())
            .map(|j| zoo_times.job(j)[0])
            .sum::<f64>()
    });
    // --- cluster: the full topology × world sweep ------------------------
    // 2 topologies × 9 world sizes up to 256 ranks, all composed on a
    // single cached plan evaluation — the collective model is a cheap
    // analytic epilogue, so this should sit close to `single_dest`.
    let cluster_topologies = [habitat::comm::Topology::DGX, habitat::comm::Topology::CLOUD];
    let cluster_worlds = [1usize, 2, 4, 8, 16, 32, 64, 128, 256];
    let cluster_params = habitat::comm::ClusterParams::default();
    bench("cluster/sweep_256_ranks", || {
        engine
            .predict_cluster(
                "resnet50",
                32,
                Device::Rtx2070,
                Device::V100,
                Precision::Fp32,
                &cluster_topologies,
                &cluster_worlds,
                &cluster_params,
            )
            .unwrap()
            .configs
            .len()
    });

    // --- service: the layered request core -------------------------------
    // One wire request through the transport-agnostic dispatcher, via
    // both transport entry points: `handle_line` (TCP's path: parse →
    // route → serialize → metrics) and `dispatch_http` (HTTP's path:
    // same routing plus the outcome envelope). The engine cache is warm,
    // so this measures pure protocol + dispatch overhead; the
    // `http_vs_tcp_dispatch` ratio in BENCH_predictor.json is expected
    // to sit near 1.0 — the transports share one brain by construction.
    let service = habitat::coordinator::PredictionService::with_predictor(
        HybridPredictor::wave_only(),
    );
    let predict_line = r#"{"model":"resnet50","batch":32,"origin":"rtx2070","dest":"v100"}"#;
    service.handle_line(predict_line); // warm the trace/plan cache
    bench("service/dispatch_tcp_line/predict", || {
        service.handle_line(predict_line).len()
    });
    bench("service/dispatch_http_request/predict", || {
        service.dispatch_http(predict_line).reply.len()
    });
    let stats_line = habitat::coordinator::service::stats_request_json();
    bench("service/dispatch_tcp_line/stats", || {
        service.handle_line(&stats_line).len()
    });

    // --- engine: contended access (the sharding win) ---------------------
    // 16 threads hammering the cache. Under the old single-mutex engine
    // the hit path serialized globally; with the sharded RwLock cache the
    // aggregate should scale with cores. `contended_hit` is pure cache
    // hits (one shared hot key); `contended_mixed` adds per-thread cold
    // keys so build singleflight and hit traffic interleave.
    bench("engine/contended_hit_16_threads/resnet50", || {
        std::thread::scope(|s| {
            for _ in 0..16 {
                s.spawn(|| {
                    for _ in 0..50 {
                        engine.analyzed("resnet50", 32, Device::Rtx2070).unwrap();
                    }
                });
            }
        });
        engine.stats().trace_hits
    });
    let mixed_engine = PredictionEngine::wave_only();
    let mut mixed_round = 0usize;
    bench("engine/contended_mixed_hit_build_16_threads/mlp", || {
        // Fresh batch sizes every round so each round pays 4 real
        // tracking passes while 16 threads pound the hit path.
        mixed_round += 1;
        let base = mixed_round * 4;
        std::thread::scope(|s| {
            for t in 0..16usize {
                let mixed_engine = &mixed_engine;
                s.spawn(move || {
                    for i in 0..20usize {
                        let batch = base + (t + i) % 4;
                        mixed_engine.analyzed("mlp", batch, Device::T4).unwrap();
                    }
                });
            }
        });
        mixed_engine.stats().trace_misses
    });
    bench("engine/contended_stats_snapshot", || {
        // Lock-free counter snapshots must stay cheap while 8 threads
        // hit the cache.
        std::thread::scope(|s| {
            for _ in 0..8 {
                s.spawn(|| {
                    for _ in 0..25 {
                        engine.analyzed("resnet50", 32, Device::Rtx2070).unwrap();
                    }
                });
            }
            for _ in 0..1000 {
                std::hint::black_box(engine.stats());
            }
        });
        engine.stats().trace_hits
    });

    let stats = engine.stats();
    println!(
        "(engine counters: trace {} hits / {} misses; {} plan builds; {} workers; wave table {} hits / {} misses; simd {}, process-wide)",
        stats.trace_hits,
        stats.trace_misses,
        stats.plan_builds,
        stats.workers,
        stats.wave_hits,
        stats.wave_misses,
        stats.simd
    );

    match habitat::runtime::predictor_from_artifacts("artifacts") {
        Ok(hybrid) => {
            for model in habitat::models::MODEL_NAMES {
                let graph = habitat::models::by_name(model, 32).unwrap();
                let trace = OperationTracker::new(Device::Rtx2070).track(&graph);
                bench(&format!("predict/hybrid/{model}"), || {
                    hybrid.predict(&trace, Device::V100).run_time_ms()
                });
            }
        }
        Err(e) => println!("(skipping hybrid benches: {e})"),
    }

    // --- cold start: parallel plan compile + warm restore from the store --
    // Last so the registry inflation below cannot perturb the benches
    // above. A fleet of synthetic devices makes per-device lane work
    // dominate the build, which is exactly the regime the parallel
    // compiler (one work-claimed chunk per device) is built for.
    for i in 0..32u32 {
        let desc = habitat::NewDevice::new(
            &format!("sim-bench-{i:02}"),
            40 + (i % 8) * 8,
            1200.0 + f64::from(i) * 25.0,
            400.0 + f64::from(i) * 20.0,
            8.0 + f64::from(i) * 0.5,
            i % 2 == 0,
        );
        habitat::device::registry::register(&desc).expect("bench device registers");
    }
    bench("plan/build_serial/resnet50", || {
        AnalyzedPlan::build(&trace, &wave.metrics_policy).n_kernels()
    });
    bench("plan/build_parallel/resnet50", || {
        AnalyzedPlan::build_parallel(&trace, &wave.metrics_policy, engine.pool()).0.n_kernels()
    });

    // Warm restore vs recompile over the whole five-model zoo: the store
    // replays persisted lane tables and only reruns the cheap kernel
    // prefix, while recompile pays tracking + full lane computation.
    let store_dir = std::env::temp_dir()
        .join(format!("habitat-bench-store-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&store_dir);
    {
        let seeded = PredictionEngine::wave_only()
            .with_store(&store_dir)
            .expect("bench store opens");
        for model in habitat::models::MODEL_NAMES {
            seeded.analyzed(model, 32, Device::Rtx2070).unwrap();
        }
        // Dropping the engine drains the write-behind queue, so every
        // plan is on disk before the restore bench starts.
    }
    bench("engine/recompile_zoo", || {
        engine.clear_trace_cache();
        for model in habitat::models::MODEL_NAMES {
            engine.analyzed(model, 32, Device::Rtx2070).unwrap();
        }
        engine.stats().plan_builds
    });
    bench("engine/warm_restore_zoo", || {
        let restored = PredictionEngine::wave_only()
            .with_store(&store_dir)
            .expect("bench store reopens");
        let warm = restored.stats().warm_restores;
        assert_eq!(warm, habitat::models::MODEL_NAMES.len() as u64);
        warm
    });
    let _ = std::fs::remove_dir_all(&store_dir);

    let stats = engine.stats();
    println!(
        "(store counters: {} hits / {} misses; {} warm restores; {} parallel build chunks)",
        stats.store_hits, stats.store_misses, stats.warm_restores, stats.parallel_build_chunks
    );
}
