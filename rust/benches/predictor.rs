//! Benchmarks for the prediction hot path: tracking, wave scaling, and
//! the full hybrid predictor (when artifacts are available).

use habitat::device::Device;
use habitat::predict::{HybridPredictor, MetricsPolicy};
use habitat::tracker::OperationTracker;
use habitat::util::bench::bench;

fn main() {
    println!("== predictor benches ==");
    for model in habitat::models::MODEL_NAMES {
        let graph = habitat::models::by_name(model, 32).unwrap();
        bench(&format!("track/{model}/bs32"), || {
            OperationTracker::new(Device::Rtx2070).track(&graph).run_time_ms()
        });
    }

    let graph = habitat::models::resnet50(32);
    let trace = OperationTracker::new(Device::Rtx2070).track(&graph);

    let wave = HybridPredictor::wave_only();
    bench("predict/wave_only/resnet50", || {
        wave.predict(&trace, Device::V100).run_time_ms()
    });
    let warm = HybridPredictor::wave_only().with_metrics_policy(MetricsPolicy::All);
    bench("predict/wave_only_warm_cache/resnet50", || {
        warm.predict(&trace, Device::V100).run_time_ms()
    });
    let eq1 = HybridPredictor::wave_only().with_eq1(true);
    bench("predict/wave_only_eq1/resnet50", || {
        eq1.predict(&trace, Device::V100).run_time_ms()
    });

    match habitat::runtime::predictor_from_artifacts("artifacts") {
        Ok(hybrid) => {
            for model in habitat::models::MODEL_NAMES {
                let graph = habitat::models::by_name(model, 32).unwrap();
                let trace = OperationTracker::new(Device::Rtx2070).track(&graph);
                bench(&format!("predict/hybrid/{model}"), || {
                    hybrid.predict(&trace, Device::V100).run_time_ms()
                });
            }
        }
        Err(e) => println!("(skipping hybrid benches: {e})"),
    }
}
