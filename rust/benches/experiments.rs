//! End-to-end experiment benches — one per paper table/figure family.
//!
//! These time the full regeneration paths (what `habitat experiment`
//! runs), bounding the cost of reproducing the paper's evaluation.

use habitat::device::{Device, ALL_DEVICES};
use habitat::predict::HybridPredictor;
use habitat::tracker::OperationTracker;
use habitat::util::bench::bench;

fn main() {
    println!("== experiment benches ==");
    let predictor = habitat::runtime::predictor_from_artifacts("artifacts")
        .unwrap_or_else(|e| {
            println!("(wave-only: {e})");
            HybridPredictor::wave_only()
        });

    // fig1: DCGAN from T4 to 5 destinations (heuristic + habitat).
    let dcgan = habitat::models::dcgan(128);
    let t4_trace = OperationTracker::new(Device::T4).track(&dcgan);
    bench("fig1/dcgan_t4_to_all", || {
        ALL_DEVICES
            .into_iter()
            .filter(|d| *d != Device::T4)
            .map(|d| {
                habitat::predict::heuristic::flops_ratio_prediction(&t4_trace, d)
                    + predictor.predict(&t4_trace, d).run_time_ms()
            })
            .sum::<f64>()
    });

    // fig3 (single cell): one model × 3 batches × 30 pairs.
    bench("fig3/resnet50_30pairs_x_3batches", || {
        let mut total = 0.0;
        for &batch in habitat::models::eval_batch_sizes("resnet50") {
            let graph = habitat::models::resnet50(batch);
            for origin in ALL_DEVICES {
                let trace = OperationTracker::new(origin).track(&graph);
                for dest in ALL_DEVICES {
                    if dest != origin {
                        total += predictor.predict(&trace, dest).run_time_ms();
                    }
                }
            }
        }
        total
    });

    // fig6: GNMT case study (3 batches × 3 clouds).
    bench("fig6/gnmt_case_study", || {
        let mut total = 0.0;
        for &batch in habitat::models::eval_batch_sizes("gnmt") {
            let trace = OperationTracker::new(Device::P4000).track(&habitat::models::gnmt(batch));
            for dest in [Device::P100, Device::T4, Device::V100] {
                total += predictor.predict(&trace, dest).throughput();
            }
        }
        total
    });

    // fig7: DCGAN case study (2 batches × 5 dests).
    bench("fig7/dcgan_case_study", || {
        let mut total = 0.0;
        for batch in [64usize, 128] {
            let trace =
                OperationTracker::new(Device::Rtx2080Ti).track(&habitat::models::dcgan(batch));
            for dest in ALL_DEVICES {
                if dest != Device::Rtx2080Ti {
                    total += predictor.predict(&trace, dest).run_time_ms();
                }
            }
        }
        total
    });

    // amp: Habitat∘Daydream composition.
    let resnet = habitat::models::resnet50(32);
    let p4000_trace = OperationTracker::new(Device::P4000).track(&resnet);
    bench("amp/resnet50_p4000_to_2080ti", || {
        habitat::predict::amp::predict_amp(&predictor, &p4000_trace, Device::Rtx2080Ti)
            .run_time_ms()
    });

    // table1-scale dataset sampling (1 config × 6 GPUs per op family).
    bench("dataset/sample_and_measure_x100", || {
        let mut rng = habitat::util::Rng::new(7);
        let sim = habitat::sim::Simulator::default();
        let mut total = 0.0;
        for _ in 0..100 {
            for op in habitat::opgraph::MlpOp::ALL {
                let s = habitat::dataset::sample(op, &mut rng);
                total += habitat::dataset::measure(&s, Device::V100, &sim);
            }
        }
        total
    });
}
