//! Benchmarks for the ground-truth substrate: lowering + simulation.
//!
//! These paths run inside every tracker call and every ground-truth
//! evaluation, so they bound how fast the experiment harness can go.

use habitat::device::Device;
use habitat::lowering::{lower_graph, Precision};
use habitat::sim::Simulator;
use habitat::util::bench::bench;

fn main() {
    println!("== simulator benches ==");
    let sim = Simulator::default();
    let v100 = Device::V100.spec();

    for model in habitat::models::MODEL_NAMES {
        let graph = habitat::models::by_name(model, 32).unwrap();
        bench(&format!("lower_graph/{model}/bs32"), || {
            lower_graph(&graph, v100.arch, Precision::Fp32).len()
        });
        bench(&format!("sim_graph/{model}/bs32/v100"), || {
            sim.graph_time_ms(v100, &graph, Precision::Fp32)
        });
    }

    // Single-kernel timing cost (the innermost hot function).
    let graph = habitat::models::resnet50(32);
    let lowered = lower_graph(&graph, v100.arch, Precision::Fp32);
    let kernels: Vec<_> = lowered.iter().flat_map(|(_, _, ks)| ks.clone()).collect();
    println!("({} kernels in resnet50/bs32)", kernels.len());
    bench("kernel_time_ms/resnet50_all_kernels", || {
        kernels
            .iter()
            .map(|k| sim.kernel_time_ms(v100, k, Precision::Fp32))
            .sum::<f64>()
    });
}
