//! Benchmarks for the PJRT MLP runtime: per-call latency across batch
//! sizes (bucket padding), per-op-family models, and the dynamic
//! batcher's coalescing under concurrency.
//!
//! Requires `make artifacts`; prints a note and exits otherwise.

use habitat::device::Device;
use habitat::opgraph::MlpOp;
use habitat::predict::MlpBackend;
use habitat::runtime::MlpService;
use habitat::util::bench::bench;

fn features_for(op: MlpOp, n: usize) -> Vec<Vec<f64>> {
    // Plausible mid-range configs per family.
    let row = match op {
        MlpOp::Conv2d => vec![32.0, 256.0, 256.0, 3.0, 1.0, 1.0, 28.0],
        MlpOp::Lstm => vec![32.0, 1024.0, 1024.0, 50.0, 1.0, 0.0, 1.0],
        MlpOp::Bmm => vec![64.0, 50.0, 64.0, 50.0],
        MlpOp::Linear => vec![512.0, 1024.0, 1024.0, 1.0],
    };
    vec![row; n]
}

fn main() {
    println!("== runtime benches ==");
    let handle = match MlpService::spawn("artifacts".into()) {
        Ok(h) => h,
        Err(e) => {
            println!("(skipping runtime benches: {e})");
            return;
        }
    };

    // Bucket-ladder latency: 1 → 512 rows through the conv2d MLP.
    for n in [1usize, 8, 32, 128, 512] {
        let rows = features_for(MlpOp::Conv2d, n);
        bench(&format!("mlp_predict/conv2d/rows={n}"), || {
            handle.predict_batch(MlpOp::Conv2d, &rows, Device::V100).unwrap()
        });
    }

    // Per-family latency at a typical per-trace row count.
    for op in MlpOp::ALL {
        let rows = features_for(op, 32);
        bench(&format!("mlp_predict/{op}/rows=32"), || {
            handle.predict_batch(op, &rows, Device::T4).unwrap()
        });
    }

    // Dynamic batching under concurrency: 8 threads × small requests.
    let before = handle.stats().executions.load(std::sync::atomic::Ordering::Relaxed);
    bench("mlp_predict/conv2d/8threads_x_8rows", || {
        std::thread::scope(|s| {
            for _ in 0..8 {
                let h = handle.clone();
                s.spawn(move || {
                    h.predict_batch(MlpOp::Conv2d, &features_for(MlpOp::Conv2d, 8), Device::V100)
                        .unwrap()
                });
            }
        });
    });
    let after = handle.stats().executions.load(std::sync::atomic::Ordering::Relaxed);
    let requests = handle.stats().requests.load(std::sync::atomic::Ordering::Relaxed);
    println!(
        "(batcher coalescing over the whole run: {requests} requests → {} executions)",
        after.max(before) // `after` includes everything
    );
}
