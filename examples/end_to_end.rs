//! End-to-end driver: the full three-layer system on a real workload.
//!
//! ```bash
//! make dataset train artifacts && cargo run --release --example end_to_end
//! ```
//!
//! Proves all layers compose (EXPERIMENTS.md records a run):
//! 1. **L1/L2 artifacts** — loads the AOT-compiled JAX+Pallas MLPs via
//!    PJRT (errors out if `make artifacts` has not been run).
//! 2. **L3 serving** — starts the batching prediction service and drives
//!    it with **concurrent** client threads issuing the paper's full
//!    Fig. 3 workload (5 models × 3 batch sizes × 30 GPU pairs = 450
//!    prediction requests), reporting latency percentiles, throughput,
//!    and the dynamic batcher's coalescing stats.
//! 3. **Accuracy** — compares every prediction against simulator ground
//!    truth and prints the paper's headline metric (avg error; paper:
//!    11.8%).

use std::sync::Arc;
use std::time::Instant;

use habitat::coordinator::{PredictionRequest, PredictionService};
use habitat::device::ALL_DEVICES;
use habitat::util::stats;

fn main() -> anyhow::Result<()> {
    // --- 1. load artifacts (hybrid predictor or bust) --------------------
    let service = Arc::new(PredictionService::new("artifacts").map_err(|e| {
        anyhow::anyhow!("{e}\nrun `make dataset train artifacts` first — this driver requires the full stack")
    })?);
    println!("loaded MLP artifacts; hybrid predictor ready");

    // --- 2. build the fig3 request load ----------------------------------
    let mut requests = Vec::new();
    for model in habitat::models::MODEL_NAMES {
        for &batch in habitat::models::eval_batch_sizes(model) {
            for origin in ALL_DEVICES {
                for dest in ALL_DEVICES {
                    if origin != dest {
                        requests.push(PredictionRequest {
                            model: model.to_string(),
                            batch,
                            origin: origin.id().to_lowercase(),
                            dest: dest.id().to_lowercase(),
                            precision: None,
                        });
                    }
                }
            }
        }
    }
    println!("issuing {} prediction requests from 8 client threads...", requests.len());

    // --- 3. drive concurrently, measure latency --------------------------
    let t0 = Instant::now();
    let chunk = requests.len().div_ceil(8);
    let mut handles = Vec::new();
    for chunk_reqs in requests.chunks(chunk).map(<[PredictionRequest]>::to_vec) {
        let service = service.clone();
        handles.push(std::thread::spawn(move || {
            let mut out = Vec::new();
            for req in chunk_reqs {
                let t = Instant::now();
                let resp = service.handle(&req).expect("prediction failed");
                out.push((req, resp, t.elapsed().as_secs_f64() * 1e3));
            }
            out
        }));
    }
    let mut results = Vec::new();
    for h in handles {
        results.extend(h.join().expect("worker panicked"));
    }
    let wall = t0.elapsed().as_secs_f64();

    let latencies: Vec<f64> = results.iter().map(|(_, _, ms)| *ms).collect();
    println!(
        "done in {wall:.2}s: {:.0} predictions/s | latency p50 {:.1} ms, p95 {:.1} ms, max {:.1} ms",
        results.len() as f64 / wall,
        stats::percentile(&latencies, 50.0),
        stats::percentile(&latencies, 95.0),
        stats::max(&latencies),
    );

    // --- 4. accuracy vs simulator ground truth ----------------------------
    let mut errs = Vec::new();
    let mut fallbacks = 0usize;
    for (req, resp, _) in &results {
        let dest = habitat::Device::parse(&req.dest).unwrap();
        let truth = habitat::experiments::ground_truth_ms(&req.model, req.batch, dest);
        errs.push(stats::ape(resp.iter_ms, truth));
        fallbacks += resp.mlp_fallbacks;
    }
    println!(
        "accuracy vs ground truth: avg {:.1}% | p95 {:.1}% | max {:.1}%  (paper: 11.8% avg) | {} MLP fallbacks",
        stats::mean(&errs) * 100.0,
        stats::percentile(&errs, 95.0) * 100.0,
        stats::max(&errs) * 100.0,
        fallbacks,
    );
    anyhow::ensure!(fallbacks == 0, "MLP fallbacks occurred — artifacts incomplete?");
    anyhow::ensure!(
        stats::mean(&errs) < 0.35,
        "end-to-end error out of expected range"
    );
    println!("END-TO-END OK");
    Ok(())
}
