//! Case study 1 (paper §5.3.1): *Should I rent a cloud GPU?*
//!
//! You develop GNMT on a P4000 workstation and wonder whether renting a
//! P100, T4, or V100 is worth it. Habitat predicts, for each cloud GPU
//! and batch size, the training throughput and the cost-normalized
//! throughput — the two numbers the decision actually needs.
//!
//! ```bash
//! cargo run --release --example case_study_cloud
//! ```

use habitat::{cost, models, Device, HybridPredictor, OperationTracker};

fn main() -> anyhow::Result<()> {
    let origin = Device::P4000;
    let clouds = [Device::P100, Device::T4, Device::V100];
    let predictor = habitat::runtime::predictor_from_artifacts("artifacts")
        .unwrap_or_else(|_| HybridPredictor::wave_only());

    println!("GNMT from your {origin}: predicted cloud performance\n");
    for batch in [16usize, 32, 64] {
        let trace = OperationTracker::new(origin).track(&models::gnmt(batch));
        let base_tput = cost::throughput(batch, trace.run_time_ms());
        println!("batch {batch}  (your P4000: {base_tput:.1} samples/s)");
        println!(
            "  {:<8} {:>12} {:>12} {:>14} {:>12}",
            "GPU", "speedup", "samples/s", "samples/s/$", "$/hr"
        );

        let mut best: Option<(Device, f64)> = None;
        for dest in clouds {
            let pred = predictor.predict(&trace, dest);
            let tput = pred.throughput();
            let cnt = cost::cost_normalized_throughput(dest, tput).unwrap();
            let price = dest.spec().rental_usd_per_hr.unwrap();
            println!(
                "  {:<8} {:>11.2}× {:>12.1} {:>14.1} {:>12.2}",
                dest.id(),
                tput / base_tput,
                tput,
                cnt,
                price
            );
            if best.map_or(true, |(_, b)| cnt > b) {
                best = Some((dest, cnt));
            }
        }
        let (winner, _) = best.unwrap();
        println!("  → most cost-efficient rental: {winner}\n");
    }
    println!("(paper's finding: V100 fastest, but the T4 wins samples/s/$ everywhere —");
    println!(" if you are not time-constrained, rent the T4 or keep the P4000.)");
    Ok(())
}
