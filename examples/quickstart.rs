//! Quickstart — the paper's Listing 1, in Rust.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```
//!
//! Tracks one ResNet-50 training iteration on an RTX 2070 (the GPU "you
//! have") and predicts the iteration execution time on a V100 (the GPU
//! "you are considering"). Uses the full hybrid predictor when
//! `artifacts/` exists (`make artifacts`), wave scaling otherwise.

use habitat::{models, Device, HybridPredictor, OperationTracker};

fn main() -> anyhow::Result<()> {
    // Equivalent of: tracker = habitat.OperationTracker(origin_device=...)
    let tracker = OperationTracker::new(Device::Rtx2070);

    // Equivalent of: with tracker.track(): run_my_training_iteration()
    let graph = models::resnet50(32);
    let trace = tracker.track(&graph);
    println!(
        "tracked {} ops of {} (batch {}): {:.2} ms/iter on {}",
        trace.ops.len(),
        trace.model,
        trace.batch_size,
        trace.run_time_ms(),
        trace.origin
    );

    // Equivalent of: trace.to_device(habitat.Device.V100).run_time_ms
    let predictor = habitat::runtime::predictor_from_artifacts("artifacts")
        .unwrap_or_else(|e| {
            eprintln!("(no MLP artifacts: {e}; falling back to wave scaling)");
            HybridPredictor::wave_only()
        });
    let pred = predictor.predict(&trace, Device::V100);
    println!(
        "Pred. iter. exec. time on V100: {:.2} ms  ({:.1} samples/s)",
        pred.run_time_ms(),
        pred.throughput()
    );

    // Habitat's purpose is comparison — print the whole device lineup.
    println!("\n{:<10} {:>12} {:>14} {:>16}", "GPU", "pred ms", "samples/s", "samples/s/$");
    for dest in habitat::device::ALL_DEVICES {
        let p = predictor.predict(&trace, dest);
        let tput = p.throughput();
        println!(
            "{:<10} {:>12.2} {:>14.1} {:>16}",
            dest.id(),
            p.run_time_ms(),
            tput,
            habitat::cost::cost_normalized_throughput(dest, tput)
                .map(|v| format!("{v:.1}"))
                .unwrap_or_else(|| "(not rented)".into())
        );
    }
    Ok(())
}
