//! Heterogeneous-cluster scheduling with Habitat predictions (intro
//! use-case 3 of the paper; Gavel-style [61] objective).
//!
//! Six training jobs — each profiled only on its owner's workstation GPU
//! — must be placed onto a small heterogeneous cluster. The scheduler's
//! throughput matrix comes entirely from Habitat's cross-GPU
//! predictions: no job ever ran on the cluster's GPUs.
//!
//! ```bash
//! cargo run --release --example cluster_scheduler
//! ```

use habitat::cluster::{schedule, Inventory, Job, ThroughputMatrix};
use habitat::{models, Device, HybridPredictor, OperationTracker};

fn main() -> anyhow::Result<()> {
    let predictor = habitat::runtime::predictor_from_artifacts("artifacts")
        .unwrap_or_else(|_| HybridPredictor::wave_only());

    // Jobs profiled on their owners' (diverse) workstation GPUs.
    let jobs = [
        ("alice/resnet50", "resnet50", 64, Device::Rtx2070),
        ("bob/gnmt", "gnmt", 32, Device::P4000),
        ("carol/transformer", "transformer", 64, Device::Rtx2080Ti),
        ("dave/dcgan", "dcgan", 128, Device::Rtx2070),
        ("erin/inception3", "inception3", 32, Device::P4000),
        ("frank/resnet50", "resnet50", 32, Device::Rtx2080Ti),
    ];
    let traces: Vec<(Job, habitat::Trace)> = jobs
        .iter()
        .map(|(name, model, batch, origin)| {
            let job = Job {
                name: name.to_string(),
                model: model.to_string(),
                batch: *batch,
                origin: *origin,
            };
            let trace =
                OperationTracker::new(*origin).track(&models::by_name(model, *batch).unwrap());
            (job, trace)
        })
        .collect();

    // The cluster: a few of each server GPU.
    let devices = [Device::V100, Device::P100, Device::T4];
    let inventory: Inventory = [(Device::V100, 2), (Device::P100, 2), (Device::T4, 2)].into();
    println!("cluster inventory: 2×V100, 2×P100, 2×T4\n");

    let matrix = ThroughputMatrix::build(&predictor, &traces, &devices);
    println!("Habitat-predicted throughput matrix (samples/s):");
    print!("{:<20}", "job");
    for d in &devices {
        print!("{:>10}", d.id());
    }
    println!();
    for (j, row) in matrix.matrix.iter().enumerate() {
        print!("{:<20}", matrix.jobs[j].name);
        for v in row {
            print!("{v:>10.1}");
        }
        println!();
    }

    let placements = schedule(&matrix, &inventory);
    println!("\ngreedy max-normalized-throughput placement:");
    let mut total_norm = 0.0;
    for p in &placements {
        println!(
            "  {:<20} → {:<8} ({:.1} samples/s, {:.0}% of its best device)",
            p.job,
            p.device.id(),
            p.throughput,
            p.normalized * 100.0
        );
        total_norm += p.normalized;
    }
    println!(
        "\nplaced {}/{} jobs; objective (Σ normalized throughput) = {:.2}",
        placements.len(),
        jobs.len(),
        total_norm
    );
    Ok(())
}
