//! Case study 2 (paper §5.3.2): *Is the V100 always better?*
//!
//! You have a 2080Ti and train DCGAN. Habitat predicts whether any other
//! GPU — including the much more expensive V100 — would actually help.
//! The paper's answer: no; DCGAN is computationally light and the V100
//! offers only ~1.1×.
//!
//! ```bash
//! cargo run --release --example case_study_v100
//! ```

use habitat::{models, Device, HybridPredictor, OperationTracker};

fn main() -> anyhow::Result<()> {
    let origin = Device::Rtx2080Ti;
    let predictor = habitat::runtime::predictor_from_artifacts("artifacts")
        .unwrap_or_else(|_| HybridPredictor::wave_only());

    for batch in [64usize, 128] {
        let trace = OperationTracker::new(origin).track(&models::dcgan(batch));
        let base = trace.run_time_ms();
        println!("DCGAN batch {batch}: {base:.1} ms/iter on your {origin}");
        println!("  {:<10} {:>10} {:>21}", "GPU", "pred ms", "throughput vs 2080Ti");
        for dest in habitat::device::ALL_DEVICES {
            if dest == origin {
                continue;
            }
            let pred = predictor.predict(&trace, dest);
            println!(
                "  {:<10} {:>10.1} {:>20.2}×",
                dest.id(),
                pred.run_time_ms(),
                base / pred.run_time_ms()
            );
        }
        let v100 = predictor.predict(&trace, Device::V100);
        let speedup = base / v100.run_time_ms();
        println!(
            "  → V100 speedup {speedup:.2}×: {}\n",
            if speedup < 1.35 {
                "not worth renting — keep the 2080Ti"
            } else {
                "might be worth it if you are time-constrained"
            }
        );
    }
    Ok(())
}
