"""L1 correctness: the Pallas kernel vs the pure-jnp oracle.

This is the core numerical signal of the build path: hypothesis sweeps
shapes (including non-block-multiple, tiling-triggering, and degenerate
ones) and both activations, asserting allclose against `ref.py`.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.linear import linear_act
from compile.kernels.ref import linear_act_ref


def rand(key, shape, scale=1.0):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32) * scale


def assert_matches_ref(m, k, n, activation, key=0):
    x = rand(key, (m, k))
    w = rand(key + 1, (k, n))
    b = rand(key + 2, (n,))
    got = linear_act(x, w, b, activation)
    want = linear_act_ref(x, w, b, activation)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-3, atol=1e-4)


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 80),
    k=st.integers(1, 80),
    n=st.integers(1, 80),
    activation=st.sampled_from(["relu", "none"]),
    key=st.integers(0, 1000),
)
def test_small_shapes_match_ref(m, k, n, activation, key):
    assert_matches_ref(m, k, n, activation, key)


@settings(max_examples=6, deadline=None)
@given(
    m=st.sampled_from([1, 127, 128, 129, 300]),
    k=st.sampled_from([11, 256, 300]),
    n=st.sampled_from([1, 256, 257]),
)
def test_block_boundary_shapes_match_ref(m, k, n):
    # Shapes straddling the default 128/256 block sizes (exercise padding
    # and the multi-step K grid).
    assert_matches_ref(m, k, n, "relu")


def test_multi_block_grid_accumulates():
    # Force a multi-step K reduction with small blocks.
    x = rand(0, (64, 512))
    w = rand(1, (512, 64))
    b = rand(2, (64,))
    got = linear_act(x, w, b, "none", block_m=32, block_n=64, block_k=128)
    want = linear_act_ref(x, w, b, "none")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-3, atol=1e-4)


def test_production_mlp_shapes():
    # The exact shapes the AOT artifacts use: bucket × features → hidden.
    for bucket in (1, 8, 32, 128, 512):
        assert_matches_ref(bucket, 11, 256, "relu", key=bucket)
    assert_matches_ref(512, 256, 1, "none", key=7)


def test_relu_clamps_negatives():
    x = -jnp.ones((4, 8))
    w = jnp.eye(8)
    b = jnp.zeros((8,))
    out = linear_act(x, w, b, "relu")
    assert (np.asarray(out) == 0).all()


def test_bias_applied_once():
    # With x = 0 the output must equal the bias exactly (relu of it).
    x = jnp.zeros((3, 5))
    w = rand(1, (5, 7))
    b = rand(2, (7,))
    out = linear_act(x, w, b, "none")
    np.testing.assert_allclose(np.asarray(out), np.tile(np.asarray(b), (3, 1)),
                               rtol=1e-6, atol=1e-6)


def test_rejects_bad_shapes_and_activation():
    x, w, b = jnp.zeros((2, 3)), jnp.zeros((4, 5)), jnp.zeros((5,))
    with pytest.raises(ValueError):
        linear_act(x, w, b)
    with pytest.raises(ValueError):
        linear_act(jnp.zeros((2, 4)), w, b, "gelu")


def test_deterministic():
    x, w, b = rand(0, (17, 13)), rand(1, (13, 9)), rand(2, (9,))
    a = np.asarray(linear_act(x, w, b))
    c = np.asarray(linear_act(x, w, b))
    np.testing.assert_array_equal(a, c)
