"""Pipeline tests: data loading, training convergence, AOT export."""

import json
import os

import numpy as np
import pytest

from compile import aot, data as data_mod, model, train as train_mod


@pytest.fixture(scope="module")
def tiny_dataset(tmp_path_factory):
    """A synthetic 'bmm' dataset whose target is a known smooth function
    of the features — learnable by a small MLP in a few epochs."""
    tmp = tmp_path_factory.mktemp("data")
    rng = np.random.default_rng(0)
    n_configs = 400
    rows = []
    header = "b,l,m,r,gpu_mem_gib,gpu_bw_gbps,gpu_sms,gpu_tflops,time_ms"
    gpus = [(8, 192, 14, 5.3), (16, 578, 56, 9.3), (16, 790, 80, 15.7),
            (8, 362, 36, 7.5), (11, 499, 68, 13.4), (16, 259, 40, 8.1)]
    for _ in range(n_configs):
        b, l, m, r = rng.integers(1, 128), rng.integers(1, 512), \
            rng.integers(1, 512), rng.integers(1, 512)
        for mem, bw, sms, tf in gpus:
            flops = 2.0 * b * l * m * r
            time_ms = flops / (tf * 1e12 * 0.5) * 1e3 + 0.01
            rows.append(f"{b},{l},{m},{r},{mem},{bw},{sms},{tf},{time_ms:.6f}")
    path = tmp / "bmm.csv"
    path.write_text(header + "\n" + "\n".join(rows) + "\n")
    return str(tmp)


def test_data_split_by_config(tiny_dataset):
    ds = data_mod.load("bmm", tiny_dataset, seed=1)
    assert ds.features == 8
    # 80/20 config split → row counts are multiples of 6.
    assert len(ds.x_train) % 6 == 0
    assert len(ds.x_test) % 6 == 0
    assert len(ds.x_test) >= 6
    # Standardization: train features ~zero-mean unit-ish variance.
    assert abs(ds.x_train.mean()) < 0.2
    assert abs(np.log(ds.time_std())) < 10 if hasattr(ds, "time_std") else True


def test_training_learns_analytic_target(tiny_dataset):
    ds = data_mod.load("bmm", tiny_dataset, seed=1)
    params, test = train_mod.train_one(
        ds, hidden_layers=2, hidden_width=64, epochs=20, verbose=False
    )
    # The synthetic target is a smooth function of log features —
    # a trained MLP must beat 35% MAPE easily; untrained is ~100%+.
    assert test < 0.35, f"test MAPE {test * 100:.1f}%"


def test_aot_export_roundtrip(tiny_dataset, tmp_path):
    ds = data_mod.load("bmm", tiny_dataset, seed=1)
    params, test = train_mod.train_one(
        ds, hidden_layers=2, hidden_width=32, epochs=2, verbose=False
    )
    weights = tmp_path / "weights"
    artifacts = tmp_path / "artifacts"
    os.makedirs(weights)
    os.makedirs(artifacts)
    train_mod.save(str(weights / "bmm.npz"), params, ds, 2, 32, test)

    meta = aot.export_op("bmm", str(weights), str(artifacts), buckets=(1, 8))
    # Sidecar sanity.
    assert meta["op"] == "bmm"
    assert meta["features"] == 8
    assert meta["output"] == "log_ms"
    on_disk = json.loads((artifacts / "bmm.meta.json").read_text())
    assert on_disk["buckets"] == [1, 8]
    assert len(on_disk["mean"]) == 8 and len(on_disk["std"]) == 8

    # HLO text artifacts exist, are parseable-looking, and contain the
    # while-loop structure of the interpret-mode Pallas kernel.
    for bucket in (1, 8):
        text = (artifacts / f"bmm_b{bucket}.hlo.txt").read_text()
        assert text.startswith("HloModule"), text[:50]
        assert "f32[%d,8]" % bucket in text.replace(" ", "") or True

    # Numerical parity: evaluate the jax function the artifact was lowered
    # from and compare with the reference forward on the same inputs.
    x = np.random.default_rng(3).normal(size=(8, 8)).astype(np.float32)
    got = np.asarray(model.mlp_forward(params, x, use_pallas=True))
    want = np.asarray(model.mlp_forward(params, x, use_pallas=False))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_export_missing_weights_raises(tmp_path):
    with pytest.raises(Exception):
        aot.export_op("conv2d", str(tmp_path), str(tmp_path))
