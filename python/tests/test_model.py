"""L2 tests: the MLP predictor model."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels.ref import mlp_forward_ref


def test_layer_dims_shape():
    dims = model.layer_dims(11, hidden_layers=4, hidden_width=256)
    assert dims[0] == (11, 256)
    assert dims[-1] == (256, 1)
    assert len(dims) == 5  # 4 hidden + head


def test_init_params_shapes():
    params = model.init_params(jax.random.PRNGKey(0), 8, 3, 64)
    assert len(params) == 4
    assert params[0][0].shape == (8, 64)
    assert params[-1][0].shape == (64, 1)
    assert params[-1][1].shape == (1,)


@settings(max_examples=8, deadline=None)
@given(
    rows=st.integers(1, 64),
    features=st.integers(4, 16),
    layers=st.integers(1, 4),
    width=st.sampled_from([16, 64, 256]),
)
def test_pallas_and_jnp_paths_agree(rows, features, layers, width):
    """The AOT-exported (Pallas) forward must equal the training (jnp)
    forward — otherwise the Rust runtime would serve a different model
    than was trained."""
    params = model.init_params(jax.random.PRNGKey(1), features, layers, width)
    x = jax.random.normal(jax.random.PRNGKey(2), (rows, features))
    a = model.mlp_forward(params, x, use_pallas=True)
    b = model.mlp_forward(params, x, use_pallas=False)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-5)
    # And equals the fully independent reference implementation.
    c = mlp_forward_ref(params, x)
    np.testing.assert_allclose(np.asarray(b), np.asarray(c), rtol=1e-6, atol=1e-6)


def test_loss_is_relative_error():
    """With a single linear identity layer the loss is checkable by hand."""
    params = [(jnp.ones((1, 1)), jnp.zeros((1,)))]
    x = jnp.array([[np.log(2.0)]], jnp.float32)  # prediction: ln 2
    y = jnp.array([np.log(1.0)], jnp.float32)    # truth: ln 1
    # |exp(ln2 - ln1) - 1| = 1.0 → 100% relative error.
    loss = model.relative_error_loss(params, x, y)
    assert abs(float(loss) - 1.0) < 1e-6


def test_loss_zero_at_perfect_prediction():
    params = model.init_params(jax.random.PRNGKey(3), 4, 2, 32)
    x = jax.random.normal(jax.random.PRNGKey(4), (16, 4))
    y = model.mlp_forward(params, x, use_pallas=False)[:, 0]
    assert float(model.relative_error_loss(params, x, y)) < 1e-6


def test_gradients_flow():
    params = model.init_params(jax.random.PRNGKey(5), 4, 2, 32)
    x = jax.random.normal(jax.random.PRNGKey(6), (8, 4))
    y = jnp.zeros((8,))
    grads = jax.grad(model.relative_error_loss)(params, x, y)
    total = sum(float(jnp.abs(g).sum()) for w, b in grads for g in (w, b))
    assert total > 0.0
