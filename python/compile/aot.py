"""AOT-lower the trained MLP predictors to HLO text for the Rust runtime.

Usage: `python -m compile.aot --weights ../weights --out ../artifacts`
(normally via `make artifacts`).

Interchange format is HLO **text**, not a serialized HloModuleProto: the
image's xla_extension 0.5.1 rejects jax ≥ 0.5's 64-bit instruction ids,
while the text parser reassigns ids and round-trips cleanly (see
/opt/xla-example/README.md). Each op family gets one artifact per batch
bucket — PJRT executables are static-shaped, so the Rust side pads a
request to the smallest bucket that fits — plus a `<op>.meta.json`
sidecar with the feature statistics.

Weights are baked into the HLO as constants: the exported function takes
only the standardized feature matrix `f32[bucket, F]` and returns a
1-tuple `(f32[bucket, 1],)` of `ln(time_ms)` predictions. The forward
pass goes through the Layer-1 Pallas kernel (interpret-mode lowering), so
the kernel is part of the artifact Rust executes.
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from compile import model

OPS = ("conv2d", "lstm", "bmm", "linear")
# Batch buckets exported per op. Keep in sync with nothing — the Rust
# runtime reads the list from meta.json.
BUCKETS = (1, 8, 32, 64, 128, 256, 512)


def load_params(path):
    """Load weights + stats from a train.py npz."""
    z = np.load(path)
    params = [
        (jnp.asarray(z[f"w{i}"]), jnp.asarray(z[f"b{i}"]))
        for i in range(int(z["layers"]))
    ]
    return params, z


def to_hlo_text(lowered) -> str:
    """stablehlo → XlaComputation → HLO text (the 0.5.1-safe path)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text(print_large_constants=True)


def export_op(op: str, weights_dir: str, out_dir: str,
              buckets=BUCKETS, use_pallas: bool = True) -> dict:
    """Export all buckets + sidecar for one op family; returns the meta."""
    params, z = load_params(f"{weights_dir}/{op}.npz")
    features = int(z["features"])

    def infer(x):
        return (model.mlp_forward(params, x, use_pallas=use_pallas),)

    for bucket in buckets:
        spec = jax.ShapeDtypeStruct((bucket, features), jnp.float32)
        lowered = jax.jit(infer).lower(spec)
        text = to_hlo_text(lowered)
        path = f"{out_dir}/{op}_b{bucket}.hlo.txt"
        with open(path, "w") as f:
            f.write(text)

    meta = {
        "op": op,
        "features": features,
        "buckets": list(buckets),
        "mean": [float(v) for v in z["mean"]],
        "std": [float(v) for v in z["std"]],
        "output": "log_ms",
        "hidden_layers": int(z["hidden_layers"]),
        "hidden_width": int(z["hidden_width"]),
        "test_mape": float(z["test_mape"]),
    }
    with open(f"{out_dir}/{op}.meta.json", "w") as f:
        json.dump(meta, f, indent=1)
    return meta


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--weights", default="../weights")
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--ops", nargs="*", default=list(OPS))
    ap.add_argument("--no-pallas", action="store_true",
                    help="export the pure-jnp forward (ablation only)")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    for op in args.ops:
        npz = f"{args.weights}/{op}.npz"
        if not os.path.exists(npz):
            raise SystemExit(
                f"{npz} missing — run `make train` (or `make dataset train`) first"
            )
        meta = export_op(op, args.weights, args.out,
                         use_pallas=not args.no_pallas)
        print(f"{op}: exported buckets {meta['buckets']} "
              f"(features={meta['features']}, "
              f"test MAPE {meta['test_mape'] * 100:.1f}%) → {args.out}/")


if __name__ == "__main__":
    main()
