"""Layer-1: the fused linear(+bias)(+ReLU) Pallas kernel.

This is the compute hot-spot of Habitat's MLP predictors: every hidden
layer of every per-operation MLP funnels through this kernel, and the
AOT-exported inference HLO that the Rust runtime executes contains it.

TPU adaptation (DESIGN.md §Hardware-Adaptation): the paper's MLPs would
run through cuBLAS GEMM + separate bias/ReLU kernels on a GPU. On TPU the
same insight — keep the matrix unit fed from fast on-chip memory — is
expressed with `BlockSpec`s: the kernel tiles `x:[M,K] @ w:[K,N]` into
`(block_m × block_k) × (block_k × block_n)` VMEM-resident tiles on a
`(M/bm, N/bn, K/bk)` grid, accumulates partial products in the f32 output
tile across the K axis (revisited grid dimension), and fuses the bias add
and ReLU into the final K step — no extra HBM round-trip for the
activation, the way a separate ReLU kernel would pay on GPU.

For the production MLP shapes (K, N ≤ 512 after padding) one block covers
the whole operand, so the grid degenerates to a single step and the
kernel is one MXU-shaped matmul; the tiling path is exercised by the
hypothesis tests with larger shapes. `interpret=True` everywhere: the CPU
PJRT plugin cannot run Mosaic custom-calls, and interpret-mode lowering
produces plain HLO that both pytest and the Rust runtime execute.

VMEM footprint at the default blocks (512, 512, 512):
  x-tile 512·512·4 B = 1 MiB, w-tile 1 MiB, out-tile 1 MiB, bias 2 KiB
  →  ~3 MiB ≪ 16 MiB VMEM, with headroom for double buffering
(DESIGN.md §Perf).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tile sizes — MXU-friendly multiples of (8, 128), sized so every
# production MLP layer (≤512 wide, buckets ≤512 rows) is a SINGLE
# VMEM-resident grid step: interpret-mode Pallas pays a large per-grid-step
# cost (a while-loop iteration with dynamic slicing in the lowered HLO), and
# one 512³ step is still only ~3 MiB of VMEM at f32 — far under the 16 MiB
# budget even with double buffering (see §Perf in EXPERIMENTS.md: this
# change cut the conv2d MLP call latency ~7×).
BLOCK_M = 512
BLOCK_N = 512
BLOCK_K = 512


def _round_up(value: int, multiple: int) -> int:
    return (value + multiple - 1) // multiple * multiple


def _kernel(x_ref, w_ref, b_ref, o_ref, *, n_k: int, activation: str):
    """One (i, j, k) grid step: accumulate x_tile @ w_tile into o_ref."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(k == n_k - 1)
    def _finish():
        acc = o_ref[...] + b_ref[...]
        if activation == "relu":
            acc = jnp.maximum(acc, 0.0)
        o_ref[...] = acc


@functools.partial(
    jax.jit, static_argnames=("activation", "block_m", "block_n", "block_k")
)
def linear_act(
    x,
    w,
    b,
    activation: str = "relu",
    *,
    block_m: int = BLOCK_M,
    block_n: int = BLOCK_N,
    block_k: int = BLOCK_K,
):
    """Fused `activation(x @ w + b)` as a Pallas kernel.

    Args:
      x: ``[M, K]`` float32 input rows.
      w: ``[K, N]`` float32 weights.
      b: ``[N]`` float32 bias.
      activation: ``"relu"`` or ``"none"``.

    Shapes need not be multiples of the block sizes: operands are
    zero-padded to the block grid and the result is sliced back. Zero
    padding is exact for matmul+bias, and ReLU(0) = 0 keeps padded rows
    inert.
    """
    if activation not in ("relu", "none"):
        raise ValueError(f"unknown activation {activation!r}")
    m, k = x.shape
    k2, n = w.shape
    if k != k2 or b.shape != (n,):
        raise ValueError(f"shape mismatch: x{x.shape} w{w.shape} b{b.shape}")

    # Shrink blocks to the (padded) problem, then pad to block multiples.
    bm = min(block_m, _round_up(m, 8))
    bn = min(block_n, _round_up(n, 128))
    bk = min(block_k, _round_up(k, 128))
    mp, kp, np_ = _round_up(m, bm), _round_up(k, bk), _round_up(n, bn)
    xp = jnp.pad(x, ((0, mp - m), (0, kp - k)))
    wp = jnp.pad(w, ((0, kp - k), (0, np_ - n)))
    bp = jnp.pad(b, (0, np_ - n))

    grid = (mp // bm, np_ // bn, kp // bk)
    out = pl.pallas_call(
        functools.partial(_kernel, n_k=grid[2], activation=activation),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bn,), lambda i, j, kk: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(xp, wp, bp)
    return out[:m, :n]
