"""Pure-jnp oracle for the Pallas kernel — the correctness ground truth.

`linear_act_ref` computes exactly what `kernels.linear.linear_act`
promises, with no tiling, padding, or fusion. pytest asserts
`assert_allclose` between the two across hypothesis-generated shapes.
"""

import jax.numpy as jnp


def linear_act_ref(x, w, b, activation: str = "relu"):
    """Reference `activation(x @ w + b)` in plain jnp (f32 accumulate)."""
    out = jnp.dot(x.astype(jnp.float32), w.astype(jnp.float32)) + b.astype(jnp.float32)
    if activation == "relu":
        out = jnp.maximum(out, 0.0)
    elif activation != "none":
        raise ValueError(f"unknown activation {activation!r}")
    return out


def mlp_forward_ref(params, x):
    """Reference MLP forward: hidden ReLU layers, linear head.

    `params` is a list of `(w, b)` pairs; returns `[M, out]`.
    """
    h = x
    for i, (w, b) in enumerate(params):
        last = i == len(params) - 1
        h = linear_act_ref(h, w, b, activation="none" if last else "relu")
    return h
