"""Dataset loading + preprocessing shared by train.py and sweep.py.

Reads the CSVs emitted by `habitat dataset` (one per op family; schema in
`rust/src/dataset/mod.rs`), applies the paper's §4.3.3 preprocessing —
standardize inputs with training-set statistics — on log1p-transformed
features, and splits 80/20 **by configuration** so that no configuration
evaluated in the test set ever appears in training (the paper's
guarantee; rows for the same config on different GPUs never straddle the
split).
"""

import dataclasses

import numpy as np

OPS = ("conv2d", "lstm", "bmm", "linear")
GPUS_PER_CONFIG = 6


@dataclasses.dataclass
class Dataset:
    op: str
    feature_names: list
    # Standardization stats over log1p(features), training split only.
    mean: np.ndarray
    std: np.ndarray
    # Standardized features and ln(time_ms) targets.
    x_train: np.ndarray
    y_train: np.ndarray
    x_test: np.ndarray
    y_test: np.ndarray

    @property
    def features(self) -> int:
        return self.x_train.shape[1]


def load_csv(path: str):
    """(header, float matrix) from a habitat dataset CSV."""
    with open(path) as f:
        header = f.readline().strip().split(",")
    data = np.loadtxt(path, delimiter=",", skiprows=1, dtype=np.float64)
    if data.ndim == 1:
        data = data[None, :]
    return header, data


def load(op: str, data_dir: str, test_frac: float = 0.2, seed: int = 0) -> Dataset:
    """Load one op family's dataset with the §4.3.3 preprocessing."""
    header, data = load_csv(f"{data_dir}/{op}.csv")
    assert header[-1] == "time_ms", f"unexpected schema in {op}.csv"
    raw_x = data[:, :-1]
    time_ms = data[:, -1]
    assert (time_ms > 0).all(), "non-positive measured time"

    # Group rows by configuration (GPUS_PER_CONFIG consecutive rows share
    # a config by construction) and split on configs.
    n_configs = len(data) // GPUS_PER_CONFIG
    rng = np.random.default_rng(seed)
    order = rng.permutation(n_configs)
    n_test = max(1, int(n_configs * test_frac))
    test_configs = np.zeros(n_configs, dtype=bool)
    test_configs[order[:n_test]] = True
    row_is_test = np.repeat(test_configs, GPUS_PER_CONFIG)
    # Tail rows (partial config group) go to train.
    if len(row_is_test) < len(data):
        row_is_test = np.concatenate(
            [row_is_test, np.zeros(len(data) - len(row_is_test), dtype=bool)]
        )

    logx = np.log1p(np.maximum(raw_x, 0.0))
    y = np.log(time_ms)

    mean = logx[~row_is_test].mean(axis=0)
    std = logx[~row_is_test].std(axis=0)
    std = np.where(std < 1e-12, 1.0, std)
    x = (logx - mean) / std

    return Dataset(
        op=op,
        feature_names=header[:-1],
        mean=mean,
        std=std,
        x_train=x[~row_is_test].astype(np.float32),
        y_train=y[~row_is_test].astype(np.float32),
        x_test=x[row_is_test].astype(np.float32),
        y_test=y[row_is_test].astype(np.float32),
    )
