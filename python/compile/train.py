"""Train the four MLP predictors (paper §4.3.3).

Usage: `python -m compile.train --data ../data --out ../weights`
(normally via `make train`).

Follows the paper's recipe, scaled for CPU: Adam, lr 5e-4 halved to 1e-4
after half the epochs, weight decay 1e-4, batch 512, MAPE loss, 80/20
config-level split. Saves per-op `<op>.npz` containing the weights, the
feature statistics, the architecture, and the test MAPE.
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from compile import data as data_mod
from compile import model


def make_adam():
    """Adam update as a jit-able pure function over pytrees."""

    def init(params):
        return {
            "m": jax.tree_util.tree_map(jnp.zeros_like, params),
            "v": jax.tree_util.tree_map(jnp.zeros_like, params),
            "t": jnp.zeros((), jnp.int32),
        }

    def update(params, grads, state, lr, weight_decay=1e-4,
               b1=0.9, b2=0.999, eps=1e-8):
        t = state["t"] + 1
        m = jax.tree_util.tree_map(lambda m_, g: b1 * m_ + (1 - b1) * g, state["m"], grads)
        v = jax.tree_util.tree_map(lambda v_, g: b2 * v_ + (1 - b2) * g * g, state["v"], grads)
        def upd(p, m_, v_):
            mhat = m_ / (1 - b1 ** t.astype(jnp.float32))
            vhat = v_ / (1 - b2 ** t.astype(jnp.float32))
            return p - lr * (mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p)
        params = jax.tree_util.tree_map(upd, params, m, v)
        return params, {"m": m, "v": v, "t": t}

    return init, update


def train_one(ds, *, hidden_layers=model.DEFAULT_HIDDEN_LAYERS,
              hidden_width=model.DEFAULT_HIDDEN_WIDTH, epochs=30,
              batch=512, lr=3e-3, seed=0, verbose=True):
    """Train one op family's MLP; returns (params, test_mape)."""
    key = jax.random.PRNGKey(seed)
    params = model.init_params(key, ds.features, hidden_layers, hidden_width)
    init, update = make_adam()
    opt = init(params)

    loss_grad = jax.jit(jax.value_and_grad(
        lambda p, x, y: model.train_loss(p, x, y, use_pallas=False)
    ))
    update = jax.jit(update)

    x_train = jnp.asarray(ds.x_train)
    y_train = jnp.asarray(ds.y_train)
    n = len(ds.x_train)
    steps_per_epoch = max(1, n // batch)
    rng = np.random.default_rng(seed)

    t0 = time.time()
    for epoch in range(epochs):
        # Paper: lr 5e-4 dropped to 1e-4 at the halfway point.
        epoch_lr = lr if epoch < epochs // 2 else lr / 5.0
        order = rng.permutation(n)
        epoch_loss = 0.0
        for s in range(steps_per_epoch):
            idx = order[s * batch:(s + 1) * batch]
            loss, grads = loss_grad(params, x_train[idx], y_train[idx])
            params, opt = update(params, grads, opt, epoch_lr)
            epoch_loss += float(loss)
        if verbose and (epoch + 1) % max(1, epochs // 6) == 0:
            test = model.mape(params, jnp.asarray(ds.x_test), jnp.asarray(ds.y_test))
            print(f"  [{ds.op}] epoch {epoch + 1:>3}/{epochs} "
                  f"train-loss {epoch_loss / steps_per_epoch:.4f} "
                  f"test-mape {test * 100:.1f}%  ({time.time() - t0:.0f}s)")
    test = model.mape(params, jnp.asarray(ds.x_test), jnp.asarray(ds.y_test))
    return params, test


def save(path, params, ds, hidden_layers, hidden_width, test_mape):
    arrays = {}
    for i, (w, b) in enumerate(params):
        arrays[f"w{i}"] = np.asarray(w)
        arrays[f"b{i}"] = np.asarray(b)
    np.savez(
        path,
        layers=len(params),
        hidden_layers=hidden_layers,
        hidden_width=hidden_width,
        features=ds.features,
        mean=ds.mean,
        std=ds.std,
        test_mape=test_mape,
        **arrays,
    )


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--data", default="../data")
    ap.add_argument("--out", default="../weights")
    ap.add_argument("--ops", nargs="*", default=list(data_mod.OPS))
    ap.add_argument("--epochs", type=int, default=30)
    ap.add_argument("--hidden-layers", type=int, default=model.DEFAULT_HIDDEN_LAYERS)
    ap.add_argument("--hidden-width", type=int, default=model.DEFAULT_HIDDEN_WIDTH)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    import os
    os.makedirs(args.out, exist_ok=True)
    for op in args.ops:
        ds = data_mod.load(op, args.data, seed=args.seed)
        print(f"{op}: {len(ds.x_train)} train / {len(ds.x_test)} test rows, "
              f"{ds.features} features")
        params, test = train_one(
            ds,
            hidden_layers=args.hidden_layers,
            hidden_width=args.hidden_width,
            epochs=args.epochs,
            seed=args.seed,
        )
        save(f"{args.out}/{op}.npz", params, ds, args.hidden_layers,
             args.hidden_width, test)
        print(f"{op}: test MAPE {test * 100:.1f}% → {args.out}/{op}.npz")


if __name__ == "__main__":
    main()
