"""Fig. 5 — MLP architecture sensitivity sweep (paper §5.2.4).

Trains each op family's MLP over a grid of hidden-layer counts and
widths, recording the test MAPE after training — the reproduction of the
paper's Fig. 5 (which swept 2–8 layers × 2^5–2^11 widths for 80 epochs and
found diminishing returns past width 2^9). Scaled defaults keep the sweep
CPU-friendly; pass --layers/--widths/--epochs to widen it.

Usage: `python -m compile.sweep --data ../data --out ../results/fig5.csv`
(normally via `make fig5`).
"""

import argparse
import os
import time

from compile import data as data_mod
from compile import train as train_mod


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--data", default="../data")
    ap.add_argument("--out", default="../results/fig5.csv")
    ap.add_argument("--ops", nargs="*", default=list(data_mod.OPS))
    ap.add_argument("--layers", nargs="*", type=int, default=[2, 4, 6, 8])
    ap.add_argument("--widths", nargs="*", type=int,
                    default=[32, 64, 128, 256, 512])
    ap.add_argument("--epochs", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    rows = ["op,hidden_layers,hidden_width,test_mape_pct"]
    for op in args.ops:
        ds = data_mod.load(op, args.data, seed=args.seed)
        for layers in args.layers:
            for width in args.widths:
                t0 = time.time()
                _, test = train_mod.train_one(
                    ds,
                    hidden_layers=layers,
                    hidden_width=width,
                    epochs=args.epochs,
                    seed=args.seed,
                    verbose=False,
                )
                print(f"{op}: layers={layers} width={width} "
                      f"test MAPE {test * 100:.1f}% ({time.time() - t0:.0f}s)")
                rows.append(f"{op},{layers},{width},{test * 100:.2f}")
    with open(args.out, "w") as f:
        f.write("\n".join(rows) + "\n")
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
