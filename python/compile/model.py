"""Layer-2: the MLP execution-time predictor in JAX (paper §3.4).

One MLP per kernel-varying operation family (conv2d, lstm, bmm, linear).
Architecture follows the paper — an input layer, `L` hidden layers of
width `H` with ReLU, and a scalar output head — with the sizes scaled for
CPU-only training (paper: 8×1024; default here: 4×256; Fig. 5 sweeps the
grid). Inputs are the op's configuration features plus four GPU hardware
features, log1p-transformed and standardized; the output is `ln(time_ms)`
(forward+backward), trained with a relative-error loss equivalent to the
paper's MAPE.

The forward pass calls the Layer-1 Pallas kernel (`kernels.linear`), so
the AOT-lowered inference HLO that Rust executes contains the kernel.
`use_pallas=False` selects the pure-jnp path (used during training, where
interpret-mode Pallas would be needlessly slow; pytest asserts the two
paths agree to float tolerance).
"""

import jax
import jax.numpy as jnp

from compile.kernels.linear import linear_act
from compile.kernels.ref import linear_act_ref

# Default architecture (see module docstring).
DEFAULT_HIDDEN_LAYERS = 4
DEFAULT_HIDDEN_WIDTH = 256


def layer_dims(features: int, hidden_layers: int = DEFAULT_HIDDEN_LAYERS,
               hidden_width: int = DEFAULT_HIDDEN_WIDTH):
    """[(in, out), ...] for every layer of the MLP."""
    dims = [(features, hidden_width)]
    for _ in range(hidden_layers - 1):
        dims.append((hidden_width, hidden_width))
    dims.append((hidden_width, 1))
    return dims


def init_params(key, features: int, hidden_layers: int = DEFAULT_HIDDEN_LAYERS,
                hidden_width: int = DEFAULT_HIDDEN_WIDTH):
    """He-initialized weights: list of (w[in,out], b[out]) pairs."""
    params = []
    for d_in, d_out in layer_dims(features, hidden_layers, hidden_width):
        key, wkey = jax.random.split(key)
        scale = jnp.sqrt(2.0 / d_in)
        params.append(
            (
                jax.random.normal(wkey, (d_in, d_out), jnp.float32) * scale,
                jnp.zeros((d_out,), jnp.float32),
            )
        )
    return params


def mlp_forward(params, x, use_pallas: bool = True):
    """Predict `ln(time_ms)` for standardized feature rows `x:[M,F]`.

    Returns `[M, 1]`. Hidden layers are fused linear+ReLU (the Pallas
    kernel); the head is linear.
    """
    dense = linear_act if use_pallas else linear_act_ref
    h = x
    for i, (w, b) in enumerate(params):
        last = i == len(params) - 1
        h = dense(h, w, b, activation="none" if last else "relu")
    return h


def train_loss(params, x, y_log, use_pallas: bool = False):
    """Log-space MAE: mean |pred − ln(t)|.

    This is the smooth training surrogate for MAPE: for small errors
    |ln(p/t)| ≈ |p/t − 1|, but unlike the raw MAPE it is symmetric in
    over/under-prediction and its gradients do not explode when the
    network is far off — which matters early in training when targets
    span five orders of magnitude. Evaluation still reports the paper's
    MAPE ([`mape`]).
    """
    pred = mlp_forward(params, x, use_pallas=use_pallas)[:, 0]
    return jnp.mean(jnp.abs(pred - y_log))


def relative_error_loss(params, x, y_log, use_pallas: bool = False):
    """Mean |predicted/measured − 1| — identical to the paper's MAPE.

    `y_log = ln(time_ms)`; with predictions in log space the MAPE is
    `|exp(pred − y_log) − 1|`, which is smooth, scale-free, and exactly
    the paper's loss after the exp head.
    """
    pred = mlp_forward(params, x, use_pallas=use_pallas)[:, 0]
    return jnp.mean(jnp.abs(jnp.expm1(pred - y_log)))


def mape(params, x, y_log, use_pallas: bool = False):
    """Test-set MAPE as a fraction (paper reports this in Fig. 5)."""
    return float(relative_error_loss(params, x, y_log, use_pallas=use_pallas))
